"""Quality/speed frontier of the sampled-core tier (repro.tiered).

One blob-stream workload (sliding window: every batch inserts ``batch``
points and expires the oldest beyond ``window``), three engines:

  * ``soa``     — the exact vectorised engine, the reference for both
                  axes (its final-window labels are "exact labels");
  * ``approx``  — ``SampledCoreDBSCAN`` at each ``sample_rate``: cores
                  from a deterministic id-hash sample, support tested
                  against the rescaled threshold k_s = round(k * rate);
  * ``tiered``  — ``TieredIndex``: approx front serves labels while the
                  exact back verifies asynchronously; here the measured
                  quantities are update throughput (front apply + queue
                  submit), label-serving throughput, and the
                  ``tiered.divergence_ari`` gauge after a flush.

Per (backend, rate): insert/delete throughput over the stream and ARI of
the final-window labelling vs the exact engine's.  JSON lands in
``results/quality_speed.json`` with an ``acceptance`` block comparing
the rate=0.1 point against the targets (>= 3x insert throughput,
ARI >= 0.9).  The ARI target is met with large margin at every rate; the
measured insert speedup at rate=0.1 is ~2.3x on this workload (the two
engines share their event-replay machinery, and its vectorised fixed
cost bounds the gap) — the JSON records the measured value either way.

  PYTHONPATH=src python -m benchmarks.quality_speed [--smoke] [--repeat N]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.api import ClusterConfig, build_index
from repro.core import adjusted_rand_index
from repro.data import blobs

RESULTS = Path(__file__).resolve().parent.parent / "results"

# the operating point: 8 well-separated blobs in d=8 drifting through a
# 24k-point window, k at the dense-bucket scale so promotions/demotions
# churn every batch (the regime the sampled tier is for)
FULL = dict(n_stream=36000, window=24000, batch=1000, d=8, n_clusters=8,
            cluster_std=0.5, k=256, t=10, eps=0.5, data_seed=3)
SMOKE = dict(n_stream=3000, window=2000, batch=500, d=8, n_clusters=4,
             cluster_std=0.4, k=32, t=8, eps=0.5, data_seed=3)


def _stream(idx, X, n_stream: int, window: int, batch: int):
    """Drive the sliding-window stream; returns timings + final labels."""
    ids: List[int] = []
    ptr = 0
    t_ins = t_del = 0.0
    for s in range(0, n_stream, batch):
        xb = X[s:s + batch]
        t0 = time.perf_counter()
        ids += idx.insert_batch(xb)
        t_ins += time.perf_counter() - t0
        n_live = len(ids) - ptr
        if n_live > window:
            drop = n_live - window
            t0 = time.perf_counter()
            idx.delete_batch(ids[ptr:ptr + drop])
            t_del += time.perf_counter() - t0
            ptr += drop
    live = ids[ptr:]
    lab = idx.labels(live)
    return t_ins, t_del, {i: lab[i] for i in live}, live


def _ari(ref: Dict[int, int], got: Dict[int, int]) -> float:
    common = sorted(set(ref) & set(got))
    return adjusted_rand_index([ref[i] for i in common],
                               [got[i] for i in common])


def run(smoke: bool = False, repeat: int = 1,
        rates: Optional[List[float]] = None) -> Dict:
    p = SMOKE if smoke else FULL
    rates = rates or ([0.1, 0.3] if smoke else [0.1, 0.3, 0.5, 1.0])
    n_stream, window, batch = p["n_stream"], p["window"], p["batch"]
    n_del = n_stream - window  # points expired over the whole stream
    X, _ = blobs(n=n_stream, d=p["d"], n_clusters=p["n_clusters"],
                 cluster_std=p["cluster_std"], seed=p["data_seed"])

    def cfg(backend: str, rate: float = 1.0, obs: bool = False):
        return ClusterConfig(d=p["d"], k=p["k"], t=p["t"], eps=p["eps"],
                             seed=0, backend=backend, sample_rate=rate,
                             obs=obs)

    def best_of(backend: str, rate: float = 1.0):
        """min-time over ``repeat`` runs (labels are deterministic)."""
        best = None
        for _ in range(repeat):
            idx = build_index(cfg(backend, rate))
            r = _stream(idx, X, n_stream, window, batch)
            idx.close()
            if best is None or r[0] < best[0]:
                best = r
        return best

    # exact reference
    si, sd, exact_labels, _ = best_of("soa")
    exact = {"backend": "soa", "insert_per_s": round(n_stream / si, 1),
             "delete_per_s": round(n_del / sd, 1),
             "insert_s": round(si, 4), "delete_s": round(sd, 4)}
    print(f"soa (exact):        ins {exact['insert_per_s']:>9.0f}/s   "
          f"del {exact['delete_per_s']:>9.0f}/s")

    sweep = []
    for rate in rates:
        ai, ad, alab, _ = best_of("approx", rate)
        ari = _ari(exact_labels, alab)
        row = {"backend": "approx", "sample_rate": rate,
               "insert_per_s": round(n_stream / ai, 1),
               "delete_per_s": round(n_del / ad, 1),
               "ari_vs_exact": round(ari, 4),
               "insert_speedup_vs_soa": round(si / ai, 3),
               "delete_speedup_vs_soa": round(sd / ad, 3)}
        sweep.append(row)
        print(f"approx rate={rate:<4}: ins {row['insert_per_s']:>9.0f}/s "
              f"({row['insert_speedup_vs_soa']:.2f}x)  "
              f"ARI={ari:.4f}")

    # tiered: updates hit front+queue; labels served from the front while
    # the exact back catches up.  Divergence gauge read after a flush so
    # the final round's diff is in.
    tiered_rows = []
    for rate in rates:
        if rate >= 1.0:
            continue  # front == back; nothing tiered about it
        idx = build_index(cfg("tiered", rate, obs=True))
        ti, td, tlab, live = _stream(idx, X, n_stream, window, batch)
        t0 = time.perf_counter()
        lab2 = idx.labels(live)
        t_lab = time.perf_counter() - t0
        idx.flush()
        snap = idx.obs.snapshot()
        div = snap["metrics"]["tiered.divergence_ari"]["value"]
        lag = snap["metrics"]["tiered.lag"]["value"]
        idx.close()
        row = {"backend": "tiered", "sample_rate": rate,
               "update_per_s": round(n_stream / ti, 1),
               "label_per_s": round(len(live) / max(t_lab, 1e-9), 1),
               "served_ari_vs_exact": round(_ari(exact_labels, tlab), 4),
               "divergence_ari": round(float(div), 4),
               "lag_after_flush": int(lag)}
        tiered_rows.append(row)
        print(f"tiered rate={rate:<4}: upd {row['update_per_s']:>9.0f}/s  "
              f"label {row['label_per_s']:>9.0f}/s  "
              f"div_ari={row['divergence_ari']:.4f}")

    at_point = next((r for r in sweep if r["sample_rate"] == 0.1), sweep[0])
    out = {
        "workload": {**p, "n_batches": n_stream // batch, "repeat": repeat,
                     "smoke": smoke},
        "exact": exact,
        "sweep": sweep + tiered_rows,
        "acceptance": {
            "sample_rate": at_point["sample_rate"],
            "insert_speedup_vs_soa": at_point["insert_speedup_vs_soa"],
            "ari_vs_exact": at_point["ari_vs_exact"],
            "target_insert_speedup": 3.0,
            "target_ari": 0.9,
            "speedup_target_met":
                at_point["insert_speedup_vs_soa"] >= 3.0,
            "ari_target_met": at_point["ari_vs_exact"] >= 0.9,
        },
    }
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI")
    ap.add_argument("--repeat", type=int, default=1,
                    help="timing repeats per engine (min taken)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default results/quality_speed"
                         "[_smoke].json)")
    args = ap.parse_args(argv)
    out = run(smoke=args.smoke, repeat=args.repeat)
    RESULTS.mkdir(exist_ok=True)
    path = Path(args.out) if args.out else (
        RESULTS / ("quality_speed_smoke.json" if args.smoke
                   else "quality_speed.json"))
    path.write_text(json.dumps(out, indent=2) + "\n")
    print(f"wrote {path}")
    acc = out["acceptance"]
    print(f"rate={acc['sample_rate']}: speedup "
          f"{acc['insert_speedup_vs_soa']:.2f}x "
          f"(target {acc['target_insert_speedup']}x, "
          f"{'met' if acc['speedup_target_met'] else 'NOT met'}), "
          f"ARI {acc['ari_vs_exact']:.4f} "
          f"(target {acc['target_ari']}, "
          f"{'met' if acc['ari_target_met'] else 'NOT met'})")
    return out


if __name__ == "__main__":
    main()
