"""Query-heavy serving mix: interleaved updates + ``label()`` hot path.

The serving engine's workload is not "mutate a lot, then read once" — it
is a sliding window where every admitted request *immediately* asks for
its cluster (16-ish point queries per update batch).  PR 2's sharded
backend paid an O(n) cross-shard merge on the first ``label()`` after
any mutation; the incremental bridge turns that into
"repair-dirty-set + find".  This benchmark measures exactly that:

  * fill a window of ``n`` points, then run rounds of
    (insert batch, Q queries, delete oldest batch, Q queries);
  * the **first** ``label()`` after each mutation is recorded separately
    (`after_update`) — that is the query that used to absorb the rebuild;
  * sweeps shards × workers × incremental on/off, writes p50/p99 query
    latency and update throughput to ``results/serving_mix.json``.

  PYTHONPATH=src python -m benchmarks.serving_mix            # full sweep
  PYTHONPATH=src python -m benchmarks.serving_mix --smoke --workers 2
  PYTHONPATH=src python -m benchmarks.serving_mix --smoke --transport process

``--chaos kill-one`` switches from sweep to acceptance mode: one sharded
run where shard 0's primary worker is killed mid-workload.  The run
counts requests that surfaced errors and replays the identical mutation
schedule on an in-process oracle index; with ``--replicas R>0`` the kill
must be invisible (zero failed requests, labels bit-identical to the
oracle — the CI ``chaos-smoke`` job asserts exactly this), while
``--replicas 0`` documents the failure mode (every post-kill request
fails fast with ShardUnavailableError).

  PYTHONPATH=src python -m benchmarks.serving_mix --smoke \
      --transport tcp --replicas 2 --chaos kill-one
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.data import blobs
from repro.obs import histogram_summary, merge_snapshots, write_chrome

RESULTS = Path(__file__).resolve().parent.parent / "results"
K, T, EPS = 10, 10, 0.75


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q)) if xs else 0.0


def _kill_one(index) -> None:
    """Chaos injection: SIGKILL shard 0's primary worker process.  With
    replicas the lane promotes + resyncs; without, subsequent requests to
    that shard must fail fast (never hang)."""
    lane = index.clients[0]
    members = getattr(lane, "_members", None)
    client = members[0].client if members else lane
    proc = getattr(client, "_proc", None)
    if proc is None:
        raise SystemExit("--chaos kill-one needs --transport process or tcp "
                         "(there is no worker process to kill)")
    proc.kill()


def run_one(shards: int, workers: int, incremental: bool, *, n: int,
            batch: int, rounds: int, queries: int, inner: str = "batched",
            transport: str = "local", replicas: int = 0, chaos: str = None,
            seed: int = 0, obs: bool = False, trace_out=None) -> dict:
    X, _ = blobs(n=n + batch * (rounds + 1), d=10, n_clusters=10, seed=seed)
    cfg = ClusterConfig(d=X.shape[1], k=K, t=T, eps=EPS, seed=seed,
                        workers=workers, incremental_merge=incremental,
                        obs=obs)
    cfg = (cfg.replace(backend=inner) if shards <= 1 else
           cfg.replace(backend="sharded", shards=shards, inner_backend=inner,
                       transport=transport, replicas=replicas))
    index = build_index(cfg)
    # fault-free oracle: the same mutation schedule through in-process
    # shards.  The chaos run must end bit-identical to it — failover is
    # only correct if the user can't tell it happened.
    oracle = (build_index(cfg.replace(transport="local", replicas=0,
                                      obs=False))
              if chaos else None)
    rng = np.random.default_rng(seed)

    failed_requests: list = []

    def attempt(what, fn, *a):
        """Run one user-visible request; under chaos, surviving errors are
        counted instead of aborting the workload."""
        if chaos is None:
            return True, fn(*a)
        try:
            return True, fn(*a)
        except Exception as e:
            failed_requests.append(f"{what}: {type(e).__name__}: {e}")
            return False, None

    ids: list = []
    row = 0
    while row < n:
        ids.extend(index.insert_batch(X[row:row + batch]))
        if oracle is not None:
            oracle.insert_batch(X[row:row + batch])
        row += batch

    after_update_us: list = []   # first label() after a mutation batch
    steady_us: list = []         # subsequent queries, structure clean
    t_updates = 0.0
    n_updates = 0

    def probe():
        targets = [ids[int(j)] for j in rng.integers(0, len(ids), size=queries)]
        for qi, i in enumerate(targets):
            t0 = time.perf_counter()
            ok, _ = attempt(f"label({i})", index.label, i)
            dt = (time.perf_counter() - t0) * 1e6
            if ok:
                (after_update_us if qi == 0 else steady_us).append(dt)

    for rnd in range(rounds):
        if chaos == "kill-one" and rnd == 1:
            _kill_one(index)   # mid-workload: mutations still in flight
        t0 = time.perf_counter()
        ok, new_ids = attempt("insert_batch", index.insert_batch,
                              X[row:row + batch])
        t_updates += time.perf_counter() - t0
        if ok:
            ids.extend(new_ids)
            if oracle is not None:
                oracle.insert_batch(X[row:row + batch])
            n_updates += batch
        row += batch
        probe()
        t0 = time.perf_counter()
        ok, _ = attempt("delete_batch", index.delete_batch, ids[:batch])
        t_updates += time.perf_counter() - t0
        if ok:
            if oracle is not None:
                oracle.delete_batch(ids[:batch])
            ids = ids[batch:]
            n_updates += batch
        probe()

    t0 = time.perf_counter()
    ok, labels = attempt("labels", index.labels)
    n_clusters = (len({v for v in labels.values() if v >= 0}) if ok else -1)
    t_labels = time.perf_counter() - t0
    labels_match = None
    if oracle is not None:
        labels_match = bool(ok and labels == oracle.labels())
        oracle.close()
    # the epilogue also fans out; with replicas=0 chaos the dead shard is
    # still dead here, so degrade to placeholders instead of crashing
    ok, stats = attempt("stats", index.stats)
    stats = stats if ok else {}
    ok, live_points = attempt("len", index.__len__)
    live_points = live_points if ok else -1
    obs_row = None
    if obs and index.obs.enabled:
        # structural gauges refresh at snapshot time; the histograms the
        # workload already filled (per-op + per-shard RPC latency) ride
        # into the result row so a regression diff says *where* time went
        ok, _ = attempt("obs_refresh",
                        getattr(index, "obs_refresh", lambda: None))
        ok, snaps = attempt("obs_snapshot",
                            index.obs_snapshot
                            if hasattr(index, "obs_snapshot")
                            else lambda: [index.obs.snapshot()])
        if not ok:
            snaps = [index.obs.snapshot()]
        merged = merge_snapshots(snaps)
        obs_row = {"histograms": histogram_summary(merged["metrics"]),
                   # nonzero counters only — this is where a chaos run
                   # shows its failover.promotions / rpc.retries
                   "counters": {k: m["value"]
                                for k, m in sorted(merged["metrics"].items())
                                if m.get("type") == "counter"
                                and m.get("value")},
                   "n_spans": len(merged["spans"]),
                   "spans_dropped": merged["spans_dropped"]}
        if trace_out is not None:
            write_chrome(trace_out, merged["spans"])
            print(f"  trace: {len(merged['spans'])} spans -> {trace_out}")
    index.close()
    row = {
        "shards": shards,
        "workers": workers,
        "incremental": bool(incremental),
        "inner": inner,
        "transport": transport if shards > 1 else "local",
        "replicas": replicas if shards > 1 else 0,
        "chaos": chaos or "",
        "failed_requests": len(failed_requests),
        "failed_request_samples": failed_requests[:5],
        "labels_match_oracle": labels_match,
        "live_points": live_points,
        "updates_per_s": n_updates / t_updates,
        "label_after_update_p50_us": _pct(after_update_us, 50),
        "label_after_update_p99_us": _pct(after_update_us, 99),
        "label_steady_p50_us": _pct(steady_us, 50),
        "label_steady_p99_us": _pct(steady_us, 99),
        "labels_full_ms": t_labels * 1e3,
        "n_clusters": n_clusters,
        "n_quotient_builds": stats.get("n_quotient_builds", 0),
        "n_interesting_buckets": stats.get("n_interesting_buckets", 0),
        "n_merge_passes": stats.get("n_merge_passes", 0),
        # wire overhead (zero bytes on the local transport)
        "transport_round_trips": stats.get("transport_round_trips", 0),
        "transport_bytes_sent": stats.get("transport_bytes_sent", 0),
        "transport_bytes_received": stats.get("transport_bytes_received", 0),
    }
    if obs_row is not None:
        row["obs"] = obs_row
    return row


def run(shards=(1, 4, 8), workers=(0, 4), n: int = 16000, batch: int = 500,
        rounds: int = 4, queries: int = 16, inner: str = "batched",
        transport: str = "local", replicas: int = 0, seed: int = 0,
        obs: bool = False, trace_out=None) -> list:
    """Full sweep: every shard count with the serial/threaded fan-out and
    the incremental merge on/off (off only where it changes anything:
    S > 1).  ``transport="process"`` runs the sharded rows out-of-process
    (the incremental sweep stays on — the rebuild merge would hash the
    whole directory over per-point round trips)."""
    rows = []
    for S in shards:
        for W in (workers if S > 1 else (0,)):
            incs = (True,) if S <= 1 or transport == "process" else (True, False)
            for inc in incs:
                # the trace artifact captures the largest sharded traced
                # row (distinct rows would just overwrite each other)
                dump = (trace_out if obs and trace_out is not None
                        and S == max(shards) and W == max(workers) and inc
                        else None)
                r = run_one(S, W, inc, n=n, batch=batch, rounds=rounds,
                            queries=queries, inner=inner,
                            transport=transport, replicas=replicas,
                            seed=seed, obs=obs, trace_out=dump)
                rows.append(r)
                print(f"S={S} workers={W} incremental={str(inc):5s} "
                      f"transport={r['transport']:7s}  "
                      f"label/after-update p50={r['label_after_update_p50_us']:10.1f}us "
                      f"p99={r['label_after_update_p99_us']:10.1f}us  "
                      f"steady p50={r['label_steady_p50_us']:7.1f}us  "
                      f"{r['updates_per_s']:8.0f} updates/s")
    for S in {s for s in shards if s > 1}:
        inc = [r for r in rows if r["shards"] == S and r["incremental"]
               and r["workers"] == 0]
        reb = [r for r in rows if r["shards"] == S and not r["incremental"]
               and r["workers"] == 0]
        if inc and reb and inc[0]["label_after_update_p50_us"] > 0:
            speed = (reb[0]["label_after_update_p50_us"]
                     / inc[0]["label_after_update_p50_us"])
            print(f"S={S}: incremental label() after update is {speed:.0f}x "
                  "faster at p50 than the rebuild path")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "serving_mix.json").write_text(json.dumps(rows, indent=1))
    return rows


def run_chaos(shards: int, workers: int, *, n: int, batch: int, rounds: int,
              queries: int, inner: str, transport: str, replicas: int,
              chaos: str, seed: int = 0, obs: bool = False,
              trace_out=None) -> int:
    """Acceptance mode: one sharded run with fault injection, checked
    against the fault-free oracle.  Returns a process exit code: 0 only
    if (replicas > 0) no request failed and the final labels are
    bit-identical to the oracle, or (replicas == 0) the kill surfaced as
    fast failures rather than a hang."""
    r = run_one(shards, workers, True, n=n, batch=batch, rounds=rounds,
                queries=queries, inner=inner, transport=transport,
                replicas=replicas, chaos=chaos, seed=seed, obs=obs,
                trace_out=trace_out)
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "serving_mix_chaos.json").write_text(json.dumps([r], indent=1))
    print(f"chaos={chaos} shards={shards} replicas={replicas} "
          f"transport={transport}: {r['failed_requests']} failed requests, "
          f"labels_match_oracle={r['labels_match_oracle']}")
    for s in r["failed_request_samples"]:
        print(f"  failed: {s}")
    if replicas > 0:
        ok = r["failed_requests"] == 0 and r["labels_match_oracle"]
        if not ok:
            print("FAIL: failover was user-visible (expected zero failed "
                  "requests and oracle-identical labels)")
        return 0 if ok else 1
    # replicas=0: the kill is *supposed* to surface — reaching this line
    # at all proves nothing hung; fail only if no error surfaced.
    if r["failed_requests"] == 0:
        print("FAIL: killed a worker with replicas=0 but no request failed")
        return 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (exercises the threaded "
                         "fan-out end to end)")
    ap.add_argument("--shards", type=int, nargs="+", default=None)
    ap.add_argument("--workers", type=int, nargs="+", default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--inner", default="batched")
    ap.add_argument("--transport", default="local",
                    choices=("local", "process", "tcp"),
                    help="run the sharded rows through in-process shards, "
                         "spawned per-shard server processes, or TCP with "
                         "timeouts/retries/auth")
    ap.add_argument("--replicas", type=int, default=0,
                    help="replicas per shard (sharded rows only): a lane "
                         "of 1+R workers with failover")
    ap.add_argument("--chaos", default=None, choices=("kill-one",),
                    help="acceptance mode: kill shard 0's primary worker "
                         "mid-workload and check the run against a "
                         "fault-free oracle (single run, not a sweep)")
    ap.add_argument("--obs", action="store_true",
                    help="instrument the runs (repro.obs): per-op latency "
                         "histograms land in each result row")
    ap.add_argument("--trace-out", type=Path, default=None, metavar="PATH",
                    help="with --obs: write the largest sharded row's "
                         "Chrome trace-event dump here")
    args = ap.parse_args(argv)
    if args.trace_out is not None and not args.obs:
        ap.error("--trace-out needs --obs")
    if args.chaos is not None:
        if args.transport == "local":
            ap.error("--chaos needs --transport process or tcp")
        smoke = dict(n=1200, batch=100, rounds=3, queries=8)
        full = dict(n=16000, batch=500, rounds=4, queries=16)
        kw = smoke if args.smoke else full
        if args.n:
            kw["n"] = args.n
        raise SystemExit(run_chaos(
            shards=max(args.shards or (2,)),
            workers=max(args.workers or (0,)),
            inner=args.inner, transport=args.transport,
            replicas=args.replicas, chaos=args.chaos,
            obs=args.obs, trace_out=args.trace_out, **kw))
    if args.smoke:
        run(shards=tuple(args.shards or (1, 2)),
            workers=tuple(args.workers or (0, 2)),
            n=args.n or 1200, batch=100, rounds=3, queries=8,
            inner=args.inner, transport=args.transport,
            replicas=args.replicas, obs=args.obs, trace_out=args.trace_out)
    else:
        run(shards=tuple(args.shards or (1, 4, 8)),
            workers=tuple(args.workers or (0, 4)),
            n=args.n or 16000, inner=args.inner, transport=args.transport,
            replicas=args.replicas, obs=args.obs, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
