"""Query-heavy serving mix: interleaved updates + ``label()`` hot path.

The serving engine's workload is not "mutate a lot, then read once" — it
is a sliding window where every admitted request *immediately* asks for
its cluster (16-ish point queries per update batch).  PR 2's sharded
backend paid an O(n) cross-shard merge on the first ``label()`` after
any mutation; the incremental bridge turns that into
"repair-dirty-set + find".  This benchmark measures exactly that:

  * fill a window of ``n`` points, then run rounds of
    (insert batch, Q queries, delete oldest batch, Q queries);
  * the **first** ``label()`` after each mutation is recorded separately
    (`after_update`) — that is the query that used to absorb the rebuild;
  * sweeps shards × workers × incremental on/off, writes p50/p99 query
    latency and update throughput to ``results/serving_mix.json``.

  PYTHONPATH=src python -m benchmarks.serving_mix            # full sweep
  PYTHONPATH=src python -m benchmarks.serving_mix --smoke --workers 2
  PYTHONPATH=src python -m benchmarks.serving_mix --smoke --transport process
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.data import blobs
from repro.obs import histogram_summary, merge_snapshots, write_chrome

RESULTS = Path(__file__).resolve().parent.parent / "results"
K, T, EPS = 10, 10, 0.75


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q)) if xs else 0.0


def run_one(shards: int, workers: int, incremental: bool, *, n: int,
            batch: int, rounds: int, queries: int, inner: str = "batched",
            transport: str = "local", seed: int = 0, obs: bool = False,
            trace_out=None) -> dict:
    X, _ = blobs(n=n + batch * (rounds + 1), d=10, n_clusters=10, seed=seed)
    cfg = ClusterConfig(d=X.shape[1], k=K, t=T, eps=EPS, seed=seed,
                        workers=workers, incremental_merge=incremental,
                        obs=obs)
    cfg = (cfg.replace(backend=inner) if shards <= 1 else
           cfg.replace(backend="sharded", shards=shards, inner_backend=inner,
                       transport=transport))
    index = build_index(cfg)
    rng = np.random.default_rng(seed)

    ids: list = []
    row = 0
    while row < n:
        ids.extend(index.insert_batch(X[row:row + batch]))
        row += batch

    after_update_us: list = []   # first label() after a mutation batch
    steady_us: list = []         # subsequent queries, structure clean
    t_updates = 0.0
    n_updates = 0

    def probe():
        targets = [ids[int(j)] for j in rng.integers(0, len(ids), size=queries)]
        for qi, i in enumerate(targets):
            t0 = time.perf_counter()
            index.label(i)
            dt = (time.perf_counter() - t0) * 1e6
            (after_update_us if qi == 0 else steady_us).append(dt)

    for _ in range(rounds):
        t0 = time.perf_counter()
        ids.extend(index.insert_batch(X[row:row + batch]))
        t_updates += time.perf_counter() - t0
        row += batch
        n_updates += batch
        probe()
        t0 = time.perf_counter()
        index.delete_batch(ids[:batch])
        t_updates += time.perf_counter() - t0
        ids = ids[batch:]
        n_updates += batch
        probe()

    t0 = time.perf_counter()
    n_clusters = len({v for v in index.labels().values() if v >= 0})
    t_labels = time.perf_counter() - t0
    stats = index.stats()
    live_points = len(index)
    obs_row = None
    if obs and index.obs.enabled:
        # structural gauges refresh at snapshot time; the histograms the
        # workload already filled (per-op + per-shard RPC latency) ride
        # into the result row so a regression diff says *where* time went
        if hasattr(index, "obs_refresh"):
            index.obs_refresh()
        snaps = (index.obs_snapshot() if hasattr(index, "obs_snapshot")
                 else [index.obs.snapshot()])
        merged = merge_snapshots(snaps)
        obs_row = {"histograms": histogram_summary(merged["metrics"]),
                   "n_spans": len(merged["spans"]),
                   "spans_dropped": merged["spans_dropped"]}
        if trace_out is not None:
            write_chrome(trace_out, merged["spans"])
            print(f"  trace: {len(merged['spans'])} spans -> {trace_out}")
    index.close()
    row = {
        "shards": shards,
        "workers": workers,
        "incremental": bool(incremental),
        "inner": inner,
        "transport": transport if shards > 1 else "local",
        "live_points": live_points,
        "updates_per_s": n_updates / t_updates,
        "label_after_update_p50_us": _pct(after_update_us, 50),
        "label_after_update_p99_us": _pct(after_update_us, 99),
        "label_steady_p50_us": _pct(steady_us, 50),
        "label_steady_p99_us": _pct(steady_us, 99),
        "labels_full_ms": t_labels * 1e3,
        "n_clusters": n_clusters,
        "n_quotient_builds": stats.get("n_quotient_builds", 0),
        "n_interesting_buckets": stats.get("n_interesting_buckets", 0),
        "n_merge_passes": stats.get("n_merge_passes", 0),
        # wire overhead (zero bytes on the local transport)
        "transport_round_trips": stats.get("transport_round_trips", 0),
        "transport_bytes_sent": stats.get("transport_bytes_sent", 0),
        "transport_bytes_received": stats.get("transport_bytes_received", 0),
    }
    if obs_row is not None:
        row["obs"] = obs_row
    return row


def run(shards=(1, 4, 8), workers=(0, 4), n: int = 16000, batch: int = 500,
        rounds: int = 4, queries: int = 16, inner: str = "batched",
        transport: str = "local", seed: int = 0, obs: bool = False,
        trace_out=None) -> list:
    """Full sweep: every shard count with the serial/threaded fan-out and
    the incremental merge on/off (off only where it changes anything:
    S > 1).  ``transport="process"`` runs the sharded rows out-of-process
    (the incremental sweep stays on — the rebuild merge would hash the
    whole directory over per-point round trips)."""
    rows = []
    for S in shards:
        for W in (workers if S > 1 else (0,)):
            incs = (True,) if S <= 1 or transport == "process" else (True, False)
            for inc in incs:
                # the trace artifact captures the largest sharded traced
                # row (distinct rows would just overwrite each other)
                dump = (trace_out if obs and trace_out is not None
                        and S == max(shards) and W == max(workers) and inc
                        else None)
                r = run_one(S, W, inc, n=n, batch=batch, rounds=rounds,
                            queries=queries, inner=inner,
                            transport=transport, seed=seed, obs=obs,
                            trace_out=dump)
                rows.append(r)
                print(f"S={S} workers={W} incremental={str(inc):5s} "
                      f"transport={r['transport']:7s}  "
                      f"label/after-update p50={r['label_after_update_p50_us']:10.1f}us "
                      f"p99={r['label_after_update_p99_us']:10.1f}us  "
                      f"steady p50={r['label_steady_p50_us']:7.1f}us  "
                      f"{r['updates_per_s']:8.0f} updates/s")
    for S in {s for s in shards if s > 1}:
        inc = [r for r in rows if r["shards"] == S and r["incremental"]
               and r["workers"] == 0]
        reb = [r for r in rows if r["shards"] == S and not r["incremental"]
               and r["workers"] == 0]
        if inc and reb and inc[0]["label_after_update_p50_us"] > 0:
            speed = (reb[0]["label_after_update_p50_us"]
                     / inc[0]["label_after_update_p50_us"])
            print(f"S={S}: incremental label() after update is {speed:.0f}x "
                  "faster at p50 than the rebuild path")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "serving_mix.json").write_text(json.dumps(rows, indent=1))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny stream for CI (exercises the threaded "
                         "fan-out end to end)")
    ap.add_argument("--shards", type=int, nargs="+", default=None)
    ap.add_argument("--workers", type=int, nargs="+", default=None)
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--inner", default="batched")
    ap.add_argument("--transport", default="local",
                    choices=("local", "process"),
                    help="run the sharded rows through in-process shards "
                         "or spawned per-shard server processes")
    ap.add_argument("--obs", action="store_true",
                    help="instrument the runs (repro.obs): per-op latency "
                         "histograms land in each result row")
    ap.add_argument("--trace-out", type=Path, default=None, metavar="PATH",
                    help="with --obs: write the largest sharded row's "
                         "Chrome trace-event dump here")
    args = ap.parse_args(argv)
    if args.trace_out is not None and not args.obs:
        ap.error("--trace-out needs --obs")
    if args.smoke:
        run(shards=tuple(args.shards or (1, 2)),
            workers=tuple(args.workers or (0, 2)),
            n=args.n or 1200, batch=100, rounds=3, queries=8,
            inner=args.inner, transport=args.transport,
            obs=args.obs, trace_out=args.trace_out)
    else:
        run(shards=tuple(args.shards or (1, 4, 8)),
            workers=tuple(args.workers or (0, 4)),
            n=args.n or 16000, inner=args.inner, transport=args.transport,
            obs=args.obs, trace_out=args.trace_out)


if __name__ == "__main__":
    main()
