"""Ablations beyond the paper's tables:

  * (k, t) sensitivity — the paper remarks the hyperparameters "are not
    sensitive"; we sweep both around the defaults.
  * orphan re-attachment (DESIGN.md §3 deviation 2) on/off.
  * sequence backend: skip list (paper) vs treap (Henzinger–King).
  * repair-scan frequency (the Thm-2 fix's cost in practice).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.core import adjusted_rand_index
from repro.core.euler_tour import EulerTourForest
from repro.data import blobs

RESULTS = Path(__file__).resolve().parent.parent / "results"


def kt_sensitivity(n=6000, seed=0):
    X, y = blobs(n=n, d=10, n_clusters=10, cluster_std=0.25, seed=seed)
    rows = []
    for k in (5, 10, 20):
        for t in (5, 10, 20):
            dyn = build_index(ClusterConfig(d=10, k=k, t=t, eps=0.75,
                                            seed=seed, backend="dynamic"))
            ids = dyn.insert_batch(X)
            lab = dyn.labels(ids)
            ari = adjusted_rand_index(y, np.array([lab[i] for i in ids]))
            rows.append({"k": k, "t": t, "ari": ari})
            print(f"  k={k:3d} t={t:3d} ARI={ari:.3f}")
    spread = max(r["ari"] for r in rows) - min(r["ari"] for r in rows)
    print(f"  ARI spread over 3x3 grid: {spread:.3f} (paper: 'not sensitive')")
    return rows


def orphan_ablation(n=5000, seed=1):
    X, y = blobs(n=n, d=8, n_clusters=8, cluster_std=0.25, seed=seed)
    rows = []
    for attach in (True, False):
        dyn = build_index(ClusterConfig(d=8, k=10, t=8, eps=0.6, seed=seed,
                                        attach_orphans=attach,
                                        backend="dynamic"))
        ids = dyn.insert_batch(X)
        lab = dyn.labels(ids)
        arr = np.array([lab[i] for i in ids])
        rows.append({
            "attach_orphans": attach,
            "ari": adjusted_rand_index(y, arr),
            "noise_frac": float((arr == -1).mean()),
        })
        print(f"  attach_orphans={attach}: ARI={rows[-1]['ari']:.3f} "
              f"noise={rows[-1]['noise_frac']:.3f}")
    return rows


def backend_timing(n=4000, seed=2):
    rng = np.random.default_rng(seed)
    rows = []
    for backend in ("skiplist", "treap"):
        f = EulerTourForest(seed=seed, backend=backend)
        for v in range(n):
            f.add_node(v)
        t0 = time.perf_counter()
        for i in range(4 * n):
            u, v = int(rng.integers(n)), int(rng.integers(n))
            if u == v:
                continue
            if rng.random() < 0.6:
                f.link(u, v)
            else:
                f.cut(u, v)
        dt = time.perf_counter() - t0
        rows.append({"backend": backend, "us_per_op": dt / (4 * n) * 1e6})
        print(f"  {backend:9} {rows[-1]['us_per_op']:8.1f} us/op")
    return rows


def repair_frequency(n=6000, seed=3):
    X, _ = blobs(n=n, d=8, n_clusters=8, seed=seed)
    dyn = build_index(ClusterConfig(d=8, k=10, t=8, eps=0.6, seed=seed,
                                    backend="dynamic"))
    ids = dyn.insert_batch(X)
    n_del = n // 2
    dyn.delete_batch(ids[:n_del])
    stats = dyn.stats()
    frac = stats["n_repair_scans"] / n_del
    print(f"  repair scans: {stats['n_repair_scans']} over {n_del} deletions "
          f"({frac:.4f}/deletion), {stats['n_repair_links']} replacement links")
    return {"deletions": n_del, "repair_scans": stats["n_repair_scans"],
            "repair_links": stats["n_repair_links"], "frac": frac}


def run():
    print("== (k, t) sensitivity")
    kt = kt_sensitivity()
    print("== orphan re-attachment")
    orphan = orphan_ablation()
    print("== ETT sequence backend")
    backend = backend_timing()
    print("== Thm-2 repair frequency")
    repair = repair_frequency()
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "ablations.json").write_text(json.dumps(
        {"kt": kt, "orphan": orphan, "backend": backend, "repair": repair},
        indent=1))
    return kt, orphan, backend, repair


if __name__ == "__main__":
    run()
