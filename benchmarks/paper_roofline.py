"""Roofline for the paper's own compute layer: grid-LSH batch hashing.

The hashing pass is the TPU-side hot spot of the dynamic-DBSCAN pipeline
(host pointer updates are latency-bound and stay on CPU — DESIGN.md §3).
Arithmetic intensity is ~t integer ops per input element, so the op is
HBM-bound by construction; the question is how close each implementation
gets to the single-pass traffic floor:

  floor bytes = n·d·4 (read X) + n·t·2·4 (write keys) + params

We compare:
  * the jnp reference path's *actual* HLO traffic (parsed with the same
    analyzer as the dry-run — fusion quality determines the gap);
  * the Pallas kernel's structural traffic (its BlockSpecs stream X tiles
    through VMEM exactly once — the floor by construction);
and report the roofline time at 819 GB/s per chip.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.launch.hlo_analysis import analyze

RESULTS = Path(__file__).resolve().parent.parent / "results"
HBM_BW = 819e9


def run(n: int = 1_000_000, d: int = 20, t: int = 10):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    eta = jnp.asarray(rng.uniform(0, 1.5, t), jnp.float32)
    mix = jnp.asarray(rng.integers(1, 2**31 - 1, (2, t, d)), jnp.int32)

    jitted = jax.jit(lambda a, b, c: ref.lsh_hash(a, b, c, 1 / 1.5))
    compiled = jitted.lower(x, eta, mix).compile()
    m = analyze(compiled.as_text())

    floor = n * d * 4 + n * t * 2 * 4 + eta.nbytes + mix.nbytes
    codes_intermediate = n * t * d * 4  # if (n,t,d) codes materialise

    # wall-clock on this CPU (sanity only; roofline targets TPU v5e)
    out = jitted(x, eta, mix)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    jax.block_until_ready(jitted(x, eta, mix))
    wall = time.perf_counter() - t0

    rows = {
        "n": n, "d": d, "t": t,
        "floor_bytes": floor,
        "ref_hlo_bytes": m.hbm_bytes,
        "ref_vs_floor": m.hbm_bytes / floor,
        "codes_intermediate_bytes": codes_intermediate,
        "kernel_bytes_structural": floor,
        "roofline_time_floor_us": floor / HBM_BW * 1e6,
        "roofline_time_ref_us": m.hbm_bytes / HBM_BW * 1e6,
        "cpu_wall_us": wall * 1e6,
    }
    print(f"grid-LSH hashing, n={n:,} d={d} t={t}")
    print(f"  traffic floor          : {floor/2**20:8.1f} MiB "
          f"-> {rows['roofline_time_floor_us']:.0f} us @ 819 GB/s")
    print(f"  jnp ref path (HLO)     : {m.hbm_bytes/2**20:8.1f} MiB "
          f"({rows['ref_vs_floor']:.2f}x floor) "
          f"-> {rows['roofline_time_ref_us']:.0f} us")
    print(f"  Pallas kernel (struct.): {floor/2**20:8.1f} MiB "
          f"(VMEM-tiled single pass = floor)")
    print(f"  CPU wall (ref, 1 core) : {wall*1e6:.0f} us")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "paper_roofline.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    run()
