"""Shared benchmark utilities: streaming evaluation protocol of the paper
(§5): stream batches, recluster/update, evaluate ARI/NMI on all points.

Every clusterer is built through ``repro.api.build_index``, so one loop
drives every engine and an algo is just a backend key (legacy aliases from
the paper's table headings are accepted)."""

from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.core import adjusted_rand_index, normalized_mutual_info

# paper table headings -> registry keys
ALGO_TO_BACKEND = {
    "dydbscan": "dynamic",
    "dydbscan_batched": "batched",
    "emz": "emz-static",
    "emz_fixed": "emz-fixed",
    "sklearn": "naive",
}


def with_shards(cfg: ClusterConfig, backend: str, shards: int = 0) -> ClusterConfig:
    """Resolve a --backend/--shards CLI pair into a config (legacy algo
    aliases accepted); the wrap convention itself lives on
    ``ClusterConfig.with_shards``."""
    backend = ALGO_TO_BACKEND.get(backend, backend)
    return cfg.replace(backend=backend).with_shards(shards)


def stream_eval(
    name: str,
    X: np.ndarray,
    y: np.ndarray,
    k: int = 10,
    t: int = 10,
    eps: float = 0.75,
    batch: int = 1000,
    seed: int = 0,
    algos=("dynamic", "emz-static", "naive"),
    eval_every: Optional[int] = None,
    shards: int = 0,
) -> Dict[str, Dict]:
    """Run the paper's streaming protocol; returns per-algo time/ARI/NMI.

    ``shards`` > 1 shards the engine under test (the FIRST algo); the
    baseline columns stay unsharded for comparability."""
    cfg = ClusterConfig(d=X.shape[1], k=k, t=t, eps=eps, seed=seed)
    out: Dict[str, Dict] = {}

    for pos, algo in enumerate(algos):
        backend = ALGO_TO_BACKEND.get(algo, algo)
        index = build_index(with_shards(cfg, backend,
                                        shards if pos == 0 else 0))
        t_total = 0.0
        ids = []
        lab: Dict[int, int] = {}
        for s in range(0, len(X), batch):
            xb = X[s : s + batch]
            t0 = time.perf_counter()
            ids.extend(index.insert_batch(xb))
            lab = index.labels(ids)
            t_total += time.perf_counter() - t0
        labels = np.array([lab[i] for i in ids])
        out[algo] = {
            "time_s": t_total,
            "ari": adjusted_rand_index(y, labels),
            "nmi": normalized_mutual_info(y, labels),
        }
    return out
