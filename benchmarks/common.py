"""Shared benchmark utilities: streaming evaluation protocol of the paper
(§5): stream batches, recluster/update, evaluate ARI/NMI on all points."""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import (
    DynamicDBSCAN, EMZFixedCore, EMZRecompute, GridLSH, SklearnStyleDBSCAN,
    adjusted_rand_index, normalized_mutual_info,
)
from repro.core.batched import BatchedDynamicDBSCAN


def stream_eval(
    name: str,
    X: np.ndarray,
    y: np.ndarray,
    k: int = 10,
    t: int = 10,
    eps: float = 0.75,
    batch: int = 1000,
    seed: int = 0,
    algos=("dydbscan", "emz", "sklearn"),
    eval_every: Optional[int] = None,
) -> Dict[str, Dict]:
    """Run the paper's streaming protocol; returns per-algo time/ARI/NMI."""
    d = X.shape[1]
    lsh = GridLSH(d, eps, t, seed=seed)
    out: Dict[str, Dict] = {}

    for algo in algos:
        t_total = 0.0
        labels = None
        if algo == "dydbscan":
            inst = DynamicDBSCAN(d, k, t, eps, lsh=lsh)
            ids: List[int] = []
            for s in range(0, len(X), batch):
                xb = X[s : s + batch]
                t0 = time.perf_counter()
                for p in xb:
                    ids.append(inst.add_point(p))
                lab = inst.labels(ids)
                t_total += time.perf_counter() - t0
            labels = np.array([lab[i] for i in ids])
        elif algo == "dydbscan_batched":
            inst = BatchedDynamicDBSCAN(d, k, t, eps, seed=seed)
            ids = []
            for s in range(0, len(X), batch):
                xb = X[s : s + batch]
                t0 = time.perf_counter()
                ids.extend(inst.add_batch(xb))
                lab = inst.labels(ids)
                t_total += time.perf_counter() - t0
            labels = np.array([lab[i] for i in ids])
        elif algo == "emz":
            inst = EMZRecompute(d, k, t, eps, lsh=lsh)
            for s in range(0, len(X), batch):
                t0 = time.perf_counter()
                labels = inst.add_batch(X[s : s + batch])
                t_total += time.perf_counter() - t0
        elif algo == "emz_fixed":
            inst = EMZFixedCore(d, k, t, eps, lsh=lsh)
            for s in range(0, len(X), batch):
                t0 = time.perf_counter()
                labels = inst.add_batch(X[s : s + batch])
                t_total += time.perf_counter() - t0
        elif algo == "sklearn":
            inst = SklearnStyleDBSCAN(k, eps)
            for s in range(0, len(X), batch):
                t0 = time.perf_counter()
                labels = inst.add_batch(X[s : s + batch])
                t_total += time.perf_counter() - t0
        else:
            raise ValueError(algo)
        out[algo] = {
            "time_s": t_total,
            "ari": adjusted_rand_index(y, labels),
            "nmi": normalized_mutual_info(y, labels),
        }
    return out
