"""Kernel micro-benchmarks: lsh_hash / pairwise / flash-attention wall time
(jnp ref path on CPU; the Pallas kernels target TPU and are validated in
interpret mode) + device-hash batched-update throughput vs the sequential
host path (the beyond-paper batch optimisation)."""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ClusterConfig, build_index
from repro.data import blobs
from repro.kernels import ops

RESULTS = Path(__file__).resolve().parent.parent / "results"


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    rows = []
    rng = np.random.default_rng(0)

    # hashing: (n, d) -> (n, t, 2)
    for n, d, t in [(100_000, 20, 10), (500_000, 20, 10)]:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        eta = jnp.asarray(rng.uniform(0, 1.5, t), jnp.float32)
        mix = jnp.asarray(rng.integers(1, 2**31 - 1, (2, t, d)), jnp.int32)
        dt = _time(lambda a, b, c: ops.lsh_hash(a, b, c, inv_cell=1 / 1.5, impl="ref"),
                   x, eta, mix)
        rows.append({"bench": f"lsh_hash n={n}", "us_per_call": dt * 1e6,
                     "derived": f"{n / dt / 1e6:.1f} Mpoints/s"})

    # pairwise counts
    for n, d in [(4000, 20)]:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        dt = _time(lambda a: ops.eps_neighbor_counts(a, eps=0.75, impl="ref"), x)
        rows.append({"bench": f"pairwise n={n}", "us_per_call": dt * 1e6,
                     "derived": f"{2 * n * n * d / dt / 1e9:.1f} GFLOP/s"})

    # attention (jnp chunked fallback used by models)
    from repro.models.attention import chunked_attention
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    kv = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)), jnp.bfloat16)
    dt = _time(lambda a, b: chunked_attention(a, b, b, chunk=256), q, kv)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2  # causal half
    rows.append({"bench": "attention b1 h8 s1024", "us_per_call": dt * 1e6,
                 "derived": f"{flops / dt / 1e9:.1f} GFLOP/s"})

    # batched vs sequential dynamic updates (paper technique throughput)
    X, _ = blobs(n=20000, d=20, n_clusters=10, seed=1)
    cfg = ClusterConfig(d=20, k=10, t=10, eps=0.75, seed=0)
    t0 = time.perf_counter()
    seq = build_index(cfg.replace(backend="dynamic"))
    for p in X:
        seq.insert(p)
    dt_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = build_index(cfg.replace(backend="batched"))
    for s in range(0, len(X), 1000):
        bat.insert_batch(X[s : s + 1000])
    dt_bat = time.perf_counter() - t0
    rows.append({"bench": "dyn insert 20k seq", "us_per_call": dt_seq / len(X) * 1e6,
                 "derived": f"{len(X)/dt_seq:.0f} pts/s"})
    rows.append({"bench": "dyn insert 20k batched", "us_per_call": dt_bat / len(X) * 1e6,
                 "derived": f"{len(X)/dt_bat:.0f} pts/s ({dt_seq/dt_bat:.2f}x)"})
    for r in rows:
        print(f"{r['bench']:28} {r['us_per_call']:12.1f} us  {r['derived']}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "kernels.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    run()
