"""Kernel micro-benchmarks: lsh_hash / bucket-core / pairwise / attention
wall time (jnp ref path on CPU; the Pallas kernels target TPU and are
validated in interpret mode) + dynamic-update throughput across the three
inner engines (sequential dict, batched dict, SoA vectorised)."""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ClusterConfig, build_index
from repro.data import blobs
from repro.kernels import ops

RESULTS = Path(__file__).resolve().parent.parent / "results"


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _insert_throughput(cfg, X, backend, batch):
    t0 = time.perf_counter()
    ix = build_index(cfg.replace(backend=backend))
    for s in range(0, len(X), batch):
        ix.insert_batch(X[s:s + batch])
    return time.perf_counter() - t0


def run(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    # hashing: (n, d) -> (n, t, 2)
    hash_shapes = ([(20_000, 20, 10)] if smoke
                   else [(100_000, 20, 10), (500_000, 20, 10)])
    for n, d, t in hash_shapes:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        eta = jnp.asarray(rng.uniform(0, 1.5, t), jnp.float32)
        mix = jnp.asarray(rng.integers(1, 2**31 - 1, (2, t, d)), jnp.int32)
        dt = _time(lambda a, b, c: ops.lsh_hash(a, b, c, inv_cell=1 / 1.5, impl="ref"),
                   x, eta, mix)
        rows.append({"bench": f"lsh_hash n={n}", "us_per_call": dt * 1e6,
                     "derived": f"{n / dt / 1e6:.1f} Mpoints/s"})

    # bucket occupancy / support-count kernels (the SoA engine's inner pass)
    n, t, nb = (4_000, 8, 512) if smoke else (65_536, 8, 4_096)
    slots = jnp.asarray(rng.integers(0, nb, (n, t)), jnp.int32)
    sizes = jnp.asarray(rng.integers(0, 20, nb), jnp.int32)
    impls = [("ref", slots, sizes)]
    if not smoke:
        # interpret mode is slow; bench it on a smaller tile
        si = jnp.asarray(rng.integers(0, nb, (4_096, t)), jnp.int32)
        impls.append(("pallas_interpret", si, sizes))
    for impl, sl, sz in impls:
        ni = int(sl.shape[0])
        dt = _time(lambda a, b: ops.bucket_core_stats(a, b, k=10, impl=impl),
                   sl, sz)
        rows.append({"bench": f"bucket_core_stats[{impl}] n={ni}",
                     "us_per_call": dt * 1e6,
                     "derived": f"{ni / dt / 1e6:.1f} Mpoints/s"})
        dt = _time(lambda a: ops.slot_counts(a, n_slots=nb, impl=impl), sl)
        rows.append({"bench": f"slot_counts[{impl}] n={ni}",
                     "us_per_call": dt * 1e6,
                     "derived": f"{ni * t / dt / 1e6:.1f} Mupdates/s"})

    # pairwise counts
    for n, d in [(1_000 if smoke else 4_000, 20)]:
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        dt = _time(lambda a: ops.eps_neighbor_counts(a, eps=0.75, impl="ref"), x)
        rows.append({"bench": f"pairwise n={n}", "us_per_call": dt * 1e6,
                     "derived": f"{2 * n * n * d / dt / 1e9:.1f} GFLOP/s"})

    # attention (jnp chunked fallback used by models)
    from repro.models.attention import chunked_attention
    s_att = 256 if smoke else 1024
    q = jnp.asarray(rng.normal(size=(1, 8, s_att, 64)), jnp.bfloat16)
    kv = jnp.asarray(rng.normal(size=(1, 2, s_att, 64)), jnp.bfloat16)
    dt = _time(lambda a, b: chunked_attention(a, b, b, chunk=256), q, kv)
    flops = 4 * 1 * 8 * s_att * s_att * 64 / 2  # causal half
    rows.append({"bench": f"attention b1 h8 s{s_att}", "us_per_call": dt * 1e6,
                 "derived": f"{flops / dt / 1e9:.1f} GFLOP/s"})

    # dynamic-update throughput: sequential dict vs batched dict vs SoA
    n_dyn = 2_000 if smoke else 16_000
    batch = 250 if smoke else 1_000
    X, _ = blobs(n=n_dyn, d=20, n_clusters=10, seed=1)
    cfg = ClusterConfig(d=20, k=10, t=10, eps=0.75, seed=0)
    t0 = time.perf_counter()
    seq = build_index(cfg.replace(backend="dynamic"))
    for p in X:
        seq.insert(p)
    dt_seq = time.perf_counter() - t0
    dt_bat = _insert_throughput(cfg, X, "batched", batch)
    dt_soa = _insert_throughput(cfg, X, "soa", batch)
    rows.append({"bench": f"dyn insert {n_dyn} seq",
                 "us_per_call": dt_seq / n_dyn * 1e6,
                 "derived": f"{n_dyn / dt_seq:.0f} pts/s"})
    rows.append({"bench": f"dyn insert {n_dyn} batched",
                 "us_per_call": dt_bat / n_dyn * 1e6,
                 "derived": f"{n_dyn / dt_bat:.0f} pts/s ({dt_seq / dt_bat:.2f}x seq)"})
    rows.append({"bench": f"dyn insert {n_dyn} soa",
                 "us_per_call": dt_soa / n_dyn * 1e6,
                 "derived": f"{n_dyn / dt_soa:.0f} pts/s ({dt_bat / dt_soa:.2f}x batched)"})

    for r in rows:
        print(f"{r['bench']:36} {r['us_per_call']:12.1f} us  {r['derived']}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "kernels.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    import sys
    run(smoke="--smoke" in sys.argv)
