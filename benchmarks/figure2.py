"""Paper Figure 2 (blobs): (a) running time vs stream length; (b) ARI with
random arrival; (c) ARI with cluster-by-cluster arrival, where the
EMZFixedCore ablation is expected to collapse and DynamicDBSCAN is not.

All clusterers are built through repro.api; ``--backend`` swaps the
dynamic engine under test."""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.core import adjusted_rand_index
from repro.data import blobs

from .common import with_shards

RESULTS = Path(__file__).resolve().parent.parent / "results"
K, T, EPS = 10, 10, 0.75


def run_panel(order: str, n: int = 20000, batch: int = 1000, seed: int = 0,
              backend: str = "dynamic", shards: int = 0):
    X, y = blobs(n=n, d=10, n_clusters=10, cluster_std=0.25, seed=seed)
    if order == "cluster":
        idx = np.argsort(y, kind="stable")
        X, y = X[idx], y[idx]
    cfg = ClusterConfig(d=X.shape[1], k=K, t=T, eps=EPS, seed=seed)
    algos = {
        b: build_index(with_shards(cfg, b, shards if b == backend else 0))
        for b in dict.fromkeys((backend, "emz-static", "emz-fixed"))
    }
    curve = {a: {"n": [], "ari": [], "cum_time": []} for a in algos}
    ids = {a: [] for a in algos}
    cum = {a: 0.0 for a in algos}
    for s in range(0, n, batch):
        xb = X[s : s + batch]
        seen = s + len(xb)
        for a, inst in algos.items():
            t0 = time.perf_counter()
            ids[a].extend(inst.insert_batch(xb))
            lab = inst.labels(ids[a])
            cum[a] += time.perf_counter() - t0
            labels = np.array([lab[i] for i in ids[a]])
            curve[a]["n"].append(seen)
            curve[a]["ari"].append(adjusted_rand_index(y[:seen], labels))
            curve[a]["cum_time"].append(cum[a])
    return curve


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--panel", default="all", choices=["a", "b", "c", "all"])
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--backend", default="dynamic")
    ap.add_argument("--shards", type=int, default=0)
    args = ap.parse_args(argv)
    out = {}
    if args.panel in ("a", "b", "all"):
        print("== random arrival (panels a+b)")
        out["random"] = run_panel("random", n=args.n, backend=args.backend,
                                  shards=args.shards)
        for a, c in out["random"].items():
            print(f"  {a:10} final ARI={c['ari'][-1]:.3f} "
                  f"total={c['cum_time'][-1]:.2f}s")
    if args.panel in ("c", "all"):
        print("== cluster-by-cluster arrival (panel c)")
        out["cluster"] = run_panel("cluster", n=args.n, backend=args.backend,
                                   shards=args.shards)
        for a, c in out["cluster"].items():
            print(f"  {a:10} final ARI={c['ari'][-1]:.3f} "
                  f"total={c['cum_time'][-1]:.2f}s")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "figure2.json").write_text(json.dumps(out, indent=1))
    return out


if __name__ == "__main__":
    main()
