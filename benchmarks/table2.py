"""Paper Table 2: runtime / ARI / NMI of DyDBSCAN vs EMZ vs exact DBSCAN
under the streaming protocol, across the six datasets (offline stand-ins;
blobs is exactly the paper's synthetic mixture — see DESIGN.md §7).

Default sizes are scaled (scale=0.1) so the suite finishes on one CPU
core; --full runs the paper's n.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


from repro.data import DATASET_SPECS, blobs, dataset_standin

from .common import stream_eval

RESULTS = Path(__file__).resolve().parent.parent / "results"

# (k, t, eps) per paper §5: k=10 t=10 eps=0.75 everywhere
K, T, EPS = 10, 10, 0.75


def run(scale: float = 0.1, datasets=None, algos=None, seed: int = 0,
        shards: int = 0):
    datasets = datasets or ["letter", "mnist", "fashion-mnist", "blobs"]
    algos = algos or ("dynamic", "emz-static", "emz-fixed", "naive")
    rows = []
    for name in datasets:
        if name == "blobs":
            n, d, c = DATASET_SPECS[name]
            X, y = blobs(n=max(2000, int(n * scale)), d=d, n_clusters=c,
                         cluster_std=0.25, seed=seed)
        else:
            X, y = dataset_standin(name, seed=seed, scale=scale)
        # exact DBSCAN is O(n^2): cap its dataset size
        use = tuple(a for a in algos
                    if not (a in ("naive", "sklearn") and len(X) > 25000))
        res = stream_eval(name, X, y, k=K, t=T, eps=EPS, seed=seed, algos=use,
                          shards=shards)
        for algo, m in res.items():
            rows.append({"dataset": name, "n": len(X), "algo": algo, **m})
            print(f"{name:15} n={len(X):7d} {algo:12} "
                  f"time={m['time_s']:8.2f}s ARI={m['ari']:.3f} "
                  f"NMI={m['nmi']:.3f}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "table2.json").write_text(json.dumps(rows, indent=1))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--datasets", nargs="*", default=None)
    ap.add_argument("--backend", default="dynamic",
                    help="repro.api backend for the dynamic column")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard the engine under test across S key ranges")
    args = ap.parse_args(argv)
    run(scale=1.0 if args.full else args.scale, datasets=args.datasets,
        algos=tuple(dict.fromkeys(
            (args.backend, "emz-static", "emz-fixed", "naive"))),
        shards=args.shards)


if __name__ == "__main__":
    main()
