"""Per-update complexity scaling (Theorem 1 / Remark 1): DynamicDBSCAN's
per-update time should grow polylogarithmically with the number of live
points n, while one EMZ *recompute* grows ~linearly in n.  This is the
paper's central speedup claim, measured directly."""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.core import GridLSH, emz_cluster
from repro.data import blobs

RESULTS = Path(__file__).resolve().parent.parent / "results"
K, T, EPS = 10, 10, 0.75


def run(max_n: int = 64000, probe: int = 200, seed: int = 0,
        backend: str = "dynamic"):
    X, _ = blobs(n=max_n + probe, d=10, n_clusters=10, seed=seed)
    d = X.shape[1]
    lsh = GridLSH(d, EPS, T, seed=seed)
    dyn = build_index(ClusterConfig(d=d, k=K, t=T, eps=EPS, seed=seed,
                                    backend=backend))
    rows = []
    n = 0
    checkpoints = [1000 * 2 ** i for i in range(20) if 1000 * 2 ** i <= max_n]
    for target in checkpoints:
        dyn.insert_batch(X[n:target])
        n = target
        # per-update cost: insert+delete `probe` extra points
        t0 = time.perf_counter()
        pids = [dyn.insert(X[max_n + j]) for j in range(probe)]
        dyn.delete_batch(pids)
        dt_dyn = (time.perf_counter() - t0) / (2 * probe)
        # one static EMZ recompute at this n (what one update costs if you
        # reprocess, as Remark 1 argues)
        t0 = time.perf_counter()
        emz_cluster(X[:n], K, EPS, T, lsh=lsh)
        dt_emz = time.perf_counter() - t0
        rows.append({"n": n, "dyn_per_update_us": dt_dyn * 1e6,
                     "emz_recompute_s": dt_emz})
        print(f"n={n:7d} dyn/update={dt_dyn*1e6:9.1f}us  "
              f"emz recompute={dt_emz:7.3f}s  "
              f"speedup_per_update={dt_emz/dt_dyn:9.0f}x")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "scaling.json").write_text(json.dumps(rows, indent=1))
    # growth-rate summary: fit slope of log(time) vs log(n)
    ns = np.log([r["n"] for r in rows])
    td = np.log([r["dyn_per_update_us"] for r in rows])
    te = np.log([r["emz_recompute_s"] for r in rows])
    sd = np.polyfit(ns, td, 1)[0]
    se = np.polyfit(ns, te, 1)[0]
    print(f"log-log slope: dyn per-update {sd:.2f} (polylog ⇒ ≈0), "
          f"emz recompute {se:.2f} (linear ⇒ ≈1)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=32000)
    ap.add_argument("--backend", default="dynamic")
    args = ap.parse_args(argv)
    run(max_n=args.max_n, backend=args.backend)


if __name__ == "__main__":
    main()
