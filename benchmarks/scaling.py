"""Per-update complexity scaling (Theorem 1 / Remark 1): DynamicDBSCAN's
per-update time should grow polylogarithmically with the number of live
points n, while one EMZ *recompute* grows ~linearly in n.  This is the
paper's central speedup claim, measured directly.

``--shards 1 2 4 8`` runs the shard-count sweep instead: per-update
throughput of ``backend="sharded"`` vs S on a mixed insert/delete stream
(results/scaling_shards.json).

``--shards 1 2 4 --transport process`` runs the *transport* sweep: for
each S, update throughput with the thread-pool fan-out (``workers=S``,
GIL-bound) vs the process fan-out (``transport="process"``, one server
process per shard) — results/scaling_transport.json.  This is the
thread-vs-process comparison the RPC boundary exists for: threads only
overlap the hashing, processes parallelise the pure-Python forest
updates themselves."""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.api import ClusterConfig, build_index
from repro.core import GridLSH, emz_cluster
from repro.data import blobs

from .common import with_shards

RESULTS = Path(__file__).resolve().parent.parent / "results"
K, T, EPS = 10, 10, 0.75


def run(max_n: int = 64000, probe: int = 200, seed: int = 0,
        backend: str = "dynamic", shards: int = 0):
    X, _ = blobs(n=max_n + probe, d=10, n_clusters=10, seed=seed)
    d = X.shape[1]
    lsh = GridLSH(d, EPS, T, seed=seed)
    dyn = build_index(with_shards(
        ClusterConfig(d=d, k=K, t=T, eps=EPS, seed=seed), backend, shards))
    rows = []
    n = 0
    checkpoints = [1000 * 2 ** i for i in range(20) if 1000 * 2 ** i <= max_n]
    for target in checkpoints:
        dyn.insert_batch(X[n:target])
        n = target
        # per-update cost: insert+delete `probe` extra points
        t0 = time.perf_counter()
        pids = [dyn.insert(X[max_n + j]) for j in range(probe)]
        dyn.delete_batch(pids)
        dt_dyn = (time.perf_counter() - t0) / (2 * probe)
        # one static EMZ recompute at this n (what one update costs if you
        # reprocess, as Remark 1 argues)
        t0 = time.perf_counter()
        emz_cluster(X[:n], K, EPS, T, lsh=lsh)
        dt_emz = time.perf_counter() - t0
        rows.append({"n": n, "dyn_per_update_us": dt_dyn * 1e6,
                     "emz_recompute_s": dt_emz})
        print(f"n={n:7d} dyn/update={dt_dyn*1e6:9.1f}us  "
              f"emz recompute={dt_emz:7.3f}s  "
              f"speedup_per_update={dt_emz/dt_dyn:9.0f}x")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "scaling.json").write_text(json.dumps(rows, indent=1))
    # growth-rate summary: fit slope of log(time) vs log(n)
    ns = np.log([r["n"] for r in rows])
    td = np.log([r["dyn_per_update_us"] for r in rows])
    te = np.log([r["emz_recompute_s"] for r in rows])
    sd = np.polyfit(ns, td, 1)[0]
    se = np.polyfit(ns, te, 1)[0]
    print(f"log-log slope: dyn per-update {sd:.2f} (polylog ⇒ ≈0), "
          f"emz recompute {se:.2f} (linear ⇒ ≈1)")
    return rows


def _one_mixed_run(cfg, X, max_n: int, batch: int, probe_rounds: int) -> dict:
    """Fill to ``max_n``, then time probe rounds of the sliding-window
    update mix; returns throughput/latency plus index stats."""
    index = build_index(cfg)
    ids = []
    n = 0
    t_fill = time.perf_counter()
    while n < max_n:
        ids.extend(index.insert_batch(X[n:n + batch]))
        n += batch
    t_fill = time.perf_counter() - t_fill
    t0 = time.perf_counter()
    for _ in range(probe_rounds):
        ids.extend(index.insert_batch(X[n:n + batch]))
        n += batch
        index.delete_batch(ids[:batch])
        ids = ids[batch:]
    dt = time.perf_counter() - t0
    updates = 2 * batch * probe_rounds
    t0 = time.perf_counter()
    n_clusters = len({v for v in index.labels().values() if v >= 0})
    t_labels = time.perf_counter() - t0
    stats = index.stats()
    index.close()
    return {
        "live_points": max_n,
        "updates_per_s": updates / dt,
        "us_per_update": dt / updates * 1e6,
        "fill_s": t_fill,
        "labels_s": t_labels,
        "n_clusters": n_clusters,
        "n_boundary_buckets": stats.get("n_boundary_buckets", 0),
        "transport_round_trips": stats.get("transport_round_trips", 0),
        "transport_bytes_sent": stats.get("transport_bytes_sent", 0),
        "transport_bytes_received": stats.get("transport_bytes_received", 0),
    }


def run_transports(shards=(1, 2, 4), max_n: int = 16000, batch: int = 1000,
                   probe_rounds: int = 4, seed: int = 0,
                   inner: str = "batched"):
    """Thread-pool vs process fan-out, same mixed workload, per S.

    "thread" rows run ``transport="local", workers=S`` (the PR-3 path:
    concurrency capped by the GIL — only the numpy hashing overlaps);
    "process" rows run ``transport="process"`` (one spawned server per
    shard, updates truly parallel, protocol bytes on the wire).  Writes
    results/scaling_transport.json.
    """
    X, _ = blobs(n=max_n + batch * (probe_rounds + 1), d=10, n_clusters=10,
                 seed=seed)
    base = ClusterConfig(d=X.shape[1], k=K, t=T, eps=EPS, seed=seed)
    rows = []
    for S in shards:
        cfg_s = base.replace(backend="sharded", shards=S, inner_backend=inner)
        for mode, cfg in (
            ("thread", cfg_s.replace(workers=S, transport="local")),
            ("process", cfg_s.replace(transport="process")),
        ):
            r = {"shards": S, "mode": mode, "inner": inner,
                 **_one_mixed_run(cfg, X, max_n, batch, probe_rounds)}
            rows.append(r)
            print(f"S={S}  {mode:7s}  {r['updates_per_s']:10.0f} updates/s "
                  f"({r['us_per_update']:8.1f} us/update)  "
                  f"wire={r['transport_bytes_sent'] + r['transport_bytes_received']:>10d}B "
                  f"round_trips={r['transport_round_trips']}")
    for S in shards:
        th = next(r for r in rows if r["shards"] == S and r["mode"] == "thread")
        pr = next(r for r in rows if r["shards"] == S and r["mode"] == "process")
        print(f"S={S}: process fan-out {pr['updates_per_s']/th['updates_per_s']:.2f}x "
              "thread-pool update throughput")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "scaling_transport.json").write_text(json.dumps(rows, indent=1))
    return rows


def run_shards(shards=(1, 2, 4, 8), max_n: int = 16000, batch: int = 1000,
               probe_rounds: int = 4, seed: int = 0,
               inner: str = "batched"):
    """Per-update throughput vs shard count S on a mixed workload.

    Each S builds ``backend="sharded"`` (inner engine = ``inner``), fills
    to ``max_n`` live points in batched runs, then times ``probe_rounds``
    rounds of (insert one batch, delete the oldest batch) — the sliding-
    window update mix the serving engine produces.  An unsharded ``inner``
    reference row is included as shards=0.
    """
    X, _ = blobs(n=max_n + batch * (probe_rounds + 1), d=10, n_clusters=10,
                 seed=seed)
    rows = []
    for S in (0, *shards):
        cfg = ClusterConfig(d=X.shape[1], k=K, t=T, eps=EPS, seed=seed)
        cfg = (cfg.replace(backend=inner) if S == 0 else
               cfg.replace(backend="sharded", shards=S, inner_backend=inner))
        rows.append({"shards": S, "inner": inner,
                     **_one_mixed_run(cfg, X, max_n, batch, probe_rounds)})
        print(f"shards={S or 'off':>3}  {rows[-1]['updates_per_s']:10.0f} "
              f"updates/s  ({rows[-1]['us_per_update']:8.1f} us/update)  "
              f"labels()={rows[-1]['labels_s']*1e3:7.1f}ms  "
              f"boundary_buckets={rows[-1]['n_boundary_buckets']}")
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "scaling_shards.json").write_text(json.dumps(rows, indent=1))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-n", type=int, default=32000)
    ap.add_argument("--backend", default="dynamic")
    ap.add_argument("--shards", type=int, nargs="+", default=None,
                    help="run the shard-count sweep instead, e.g. "
                         "--shards 1 2 4 8")
    ap.add_argument("--inner", default="batched",
                    help="inner engine for the shard sweep")
    ap.add_argument("--transport", default="local",
                    choices=("local", "process"),
                    help="with --shards: 'process' runs the thread-pool "
                         "vs process fan-out comparison "
                         "(results/scaling_transport.json)")
    args = ap.parse_args(argv)
    if args.transport == "process" and not args.shards:
        ap.error("--transport process is the thread-vs-process shard "
                 "sweep; pass the shard counts too, e.g. "
                 "--shards 1 2 4 --transport process")
    if args.shards and args.transport == "process":
        run_transports(tuple(args.shards), max_n=args.max_n,
                       inner=args.inner)
    elif args.shards:
        run_shards(tuple(args.shards), max_n=args.max_n, inner=args.inner)
    else:
        run(max_n=args.max_n, backend=args.backend)


if __name__ == "__main__":
    main()
