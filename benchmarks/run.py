"""Benchmark harness: one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and a
human-readable report; JSON artifacts land in results/.

  PYTHONPATH=src python -m benchmarks.run            # CI-scale
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale n
"""

from __future__ import annotations

import argparse


def main(argv=None) -> None:
    from repro.api import available_backends

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny streams for CI: blobs-only table2, small n")
    ap.add_argument("--only", default=None,
                    choices=["table2", "figure2", "scaling", "shards",
                             "serving", "kernels", "ablations",
                             "paper_roofline", "roofline", "quality"])
    ap.add_argument("--workers", type=int, default=0,
                    help="thread-pool fan-out for the sharded backend")
    ap.add_argument("--transport", default="local",
                    choices=("local", "process"),
                    help="sharded-backend transport for the serving bench "
                         "(process = spawned per-shard server processes)")
    ap.add_argument("--backend", default="dynamic",
                    choices=available_backends(),
                    help="repro.api backend for the dynamic engine under test")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard the engine under test across S key ranges "
                         "(backend=sharded; any other backend becomes the "
                         "inner engine)")
    args = ap.parse_args(argv)

    csv_rows = []

    def emit(name, us, derived):
        csv_rows.append(f"{name},{us:.1f},{derived}")

    if args.only in (None, "table2"):
        print("\n===== Table 2: streaming time / ARI / NMI =====")
        from .table2 import run as t2
        rows = t2(scale=1.0 if args.full else (0.02 if args.smoke else 0.05),
                  datasets=["blobs"] if args.smoke else None,
                  algos=tuple(dict.fromkeys(
                      (args.backend, "emz-static", "emz-fixed", "naive"))),
                  shards=args.shards)
        for r in rows:
            emit(f"table2/{r['dataset']}/{r['algo']}",
                 r["time_s"] * 1e6,
                 f"ARI={r['ari']:.3f};NMI={r['nmi']:.3f}")

    if args.only in (None, "figure2"):
        print("\n===== Figure 2: blobs arrival-order study =====")
        from .figure2 import main as f2
        out = f2(["--n", "20000" if args.full else
                  ("2000" if args.smoke else "8000"),
                  "--backend", args.backend, "--shards", str(args.shards)])
        for order, curves in out.items():
            for algo, c in curves.items():
                emit(f"figure2/{order}/{algo}", c["cum_time"][-1] * 1e6,
                     f"ARI={c['ari'][-1]:.3f}")

    if args.only in (None, "scaling"):
        print("\n===== Update-complexity scaling (Thm 1 / Remark 1) =====")
        from .scaling import run as sc
        rows = sc(max_n=64000 if args.full else
                  (4000 if args.smoke else 16000),
                  backend=args.backend, shards=args.shards)
        for r in rows:
            emit(f"scaling/n{r['n']}", r["dyn_per_update_us"],
                 f"emz_recompute={r['emz_recompute_s']:.3f}s")

    if args.only == "shards" or (args.only is None and args.shards > 1):
        print("\n===== Shard-count scaling (update throughput vs S) =====")
        from .scaling import run_shards as ss
        inner = args.backend if args.backend != "sharded" else "batched"
        rows = ss((1, 2, 4, 8) if not args.smoke else (1, args.shards or 2),
                  max_n=16000 if args.full else
                  (2000 if args.smoke else 8000),
                  inner=inner)
        for r in rows:
            emit(f"shards/S{r['shards']}", r["us_per_update"],
                 f"updates_per_s={r['updates_per_s']:.0f};"
                 f"boundary={r['n_boundary_buckets']}")

    if args.only == "serving" or (args.only is None and args.shards > 1):
        print("\n===== Serving mix (interleaved updates + label() hot path) =====")
        from .serving_mix import run as sm
        inner = args.backend if args.backend != "sharded" else "batched"
        rows = sm(shards=(1, args.shards or 2) if args.smoke else (1, 4, 8),
                  workers=(0, args.workers) if args.workers else (0,),
                  n=1200 if args.smoke else 16000,
                  batch=100 if args.smoke else 500,
                  rounds=3 if args.smoke else 4,
                  queries=8 if args.smoke else 16,
                  inner=inner, transport=args.transport)
        for r in rows:
            emit(f"serving_mix/S{r['shards']}_w{r['workers']}_"
                 f"{'inc' if r['incremental'] else 'rebuild'}",
                 r["label_after_update_p50_us"],
                 f"steady_p50={r['label_steady_p50_us']:.1f}us;"
                 f"updates_per_s={r['updates_per_s']:.0f}")

    if args.only in (None, "kernels"):
        print("\n===== Kernel / batched-update benches =====")
        from .kernels import run as kr
        for r in kr(smoke=args.smoke):
            emit(r["bench"].replace(" ", "_"), r["us_per_call"], r["derived"])

    if args.only in (None, "ablations"):
        print("\n===== Ablations (k/t sensitivity, backends, repair) =====")
        from .ablations import run as ab
        kt, orphan, backend, repair = ab()
        for r in backend:
            emit(f"ablation/ett_{r['backend']}", r["us_per_op"], "per link/cut op")
        emit("ablation/kt_spread",
             (max(r["ari"] for r in kt) - min(r["ari"] for r in kt)) * 1e6,
             "ARI spread over 3x3 (k,t) grid")
        emit("ablation/repair_scans_per_del", repair["frac"] * 1e6,
             f"links={repair['repair_links']}")

    if args.only in (None, "paper_roofline"):
        print("\n===== Paper-technique roofline (grid-LSH hashing) =====")
        from .paper_roofline import run as pr
        rows = pr()
        emit("paper_roofline/floor", rows["roofline_time_floor_us"],
             "traffic floor @819GB/s")
        emit("paper_roofline/jnp_ref", rows["roofline_time_ref_us"],
             f"{rows['ref_vs_floor']:.2f}x floor")
        emit("paper_roofline/pallas", rows["roofline_time_floor_us"],
             "1.00x floor (VMEM single pass)")

    if args.only == "quality":
        # explicit-only: the full sweep re-times every engine on the
        # paper-scale stream, so it does not ride the default run
        print("\n===== Quality/speed frontier (sampled-core tier) =====")
        from .quality_speed import main as qs
        out = qs(["--smoke"] if args.smoke else [])
        for r in out["sweep"]:
            rate = r["sample_rate"]
            if r["backend"] == "approx":
                emit(f"quality/approx_r{rate}",
                     1e6 / r["insert_per_s"],
                     f"ARI={r['ari_vs_exact']:.4f};"
                     f"speedup={r['insert_speedup_vs_soa']:.2f}x")
            else:
                emit(f"quality/tiered_r{rate}",
                     1e6 / r["update_per_s"],
                     f"div_ari={r['divergence_ari']:.4f};"
                     f"label_per_s={r['label_per_s']:.0f}")

    if args.only in (None, "roofline"):
        print("\n===== Roofline table (from dry-run artifacts) =====")
        try:
            from repro.launch.roofline import build_table, format_table
            rows = build_table()
            print(format_table(rows))
            for r in rows:
                if r.get("status") == "ok":
                    emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                         r["bound_time_s"] * 1e6,
                         f"dominant={r['dominant']};MFU_ub={r.get('mfu_upper_bound', 0):.3f}")
        except FileNotFoundError:
            print("(no results/dryrun.json yet — run repro.launch.dryrun)")

    print("\n===== CSV =====")
    print("name,us_per_call,derived")
    for line in csv_rows:
        print(line)


if __name__ == "__main__":
    main()
