"""Deliverable (e) gate: the recorded dry-run must cover every
(architecture × shape × mesh) cell with status ok or a documented skip,
and the roofline table must derive cleanly from it."""

import json
from pathlib import Path

import pytest

from repro.configs import ARCH_IDS, SHAPES, cell_supported

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun.json"


@pytest.mark.skipif(not RESULTS.exists(), reason="run repro.launch.dryrun first")
def test_dryrun_covers_all_cells_on_both_meshes():
    recs = {(r["arch"], r["shape"], r["mesh"]): r
            for r in json.loads(RESULTS.read_text())}
    missing, failed = [], []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    missing.append((arch, shape, mesh))
                    continue
                supported, _ = cell_supported(arch, shape)
                if supported:
                    if r["status"] != "ok":
                        failed.append((arch, shape, mesh, r.get("error", "")[:80]))
                else:
                    if r["status"] != "skipped":
                        failed.append((arch, shape, mesh, "expected documented skip"))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


@pytest.mark.skipif(not RESULTS.exists(), reason="run repro.launch.dryrun first")
def test_roofline_terms_sane():
    from repro.launch.roofline import build_table

    rows = [r for r in build_table() if r.get("status") == "ok"]
    assert len(rows) >= 60
    for r in rows:
        assert r["t_comp_s"] > 0
        assert r["t_mem_s"] > 0
        assert r["dominant"] in ("compute", "memory", "collective")
        if r["shape"] == "train_4k":
            # a train step should involve nontrivial compute
            assert r["t_comp_s"] > 0.01, r
        assert 0 < r.get("useful_ratio", 1) < 10, r
