"""MoE unit tests: dense-dispatch oracle properties + EP path on a
single-device mesh (the multi-device EP equivalence runs in
test_distributed_cells.py's subprocess)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as M


@pytest.fixture
def cfg():
    return get_config("dbrx-132b").smoke()  # 4 experts top-2


def test_dense_dispatch_mixes_topk_experts(cfg):
    p, _ = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.1
    y, aux = M.moe_block_dense(p, x, cfg, jnp.float32)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.0


def test_aux_loss_minimised_by_uniform_routing(cfg):
    X = cfg.n_experts
    T = 64
    uniform = jnp.ones((T, X)) / X
    idx_uniform = jnp.tile(jnp.arange(cfg.top_k)[None], (T, 1))
    skewed = jnp.zeros((T, X)).at[:, 0].set(1.0)
    idx_skewed = jnp.zeros((T, cfg.top_k), jnp.int32)
    lu = M._aux_loss(uniform, idx_uniform, X)
    ls = M._aux_loss(skewed, idx_skewed, X)
    assert float(ls) > float(lu)
    # uniform routing hits the theoretical minimum k... f sums to top_k
    assert float(lu) == pytest.approx(cfg.top_k, rel=0.01)


def test_ep_single_device_mesh_matches_dense(cfg):
    """shard_map path with ep=1 must equal the dense oracle exactly
    (capacity effects aside — capacity is ample here)."""
    cfg1 = dataclasses.replace(cfg, capacity_factor=4.0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p, _ = M.init_moe(jax.random.PRNGKey(2), cfg1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg1.d_model)) * 0.1
    with mesh:
        y_ep, aux_ep = jax.jit(
            lambda pp, xx: M.moe_block(pp, xx, cfg1, jnp.float32, mesh)
        )(p, x)
    y_d, aux_d = M.moe_block_dense(p, x, cfg1, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y_ep), np.asarray(y_d), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(float(aux_ep), float(aux_d), rtol=1e-5)


def test_capacity_drops_bounded(cfg):
    """With capacity_factor 1.0 and adversarially skewed inputs, the EP
    output must stay finite and within the residual-friendly range (drops
    produce zeros, not garbage)."""
    cfg1 = dataclasses.replace(cfg, capacity_factor=1.0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p, _ = M.init_moe(jax.random.PRNGKey(4), cfg1)
    # identical tokens -> all route to the same experts -> heavy drops
    x = jnp.tile(
        jax.random.normal(jax.random.PRNGKey(5), (1, 1, cfg1.d_model)), (2, 16, 1)
    ) * 0.1
    with mesh:
        y, _ = jax.jit(
            lambda pp, xx: M.moe_block(pp, xx, cfg1, jnp.float32, mesh)
        )(p, x)
    a = np.asarray(y)
    assert np.isfinite(a).all()
    dense_y, _ = M.moe_block_dense(p, x, cfg1, jnp.float32)
    assert np.abs(a).max() <= np.abs(np.asarray(dense_y)).max() * 1.5 + 1e-6


def test_ep_gradients_flow_to_all_param_groups(cfg):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p, _ = M.init_moe(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, cfg.d_model)) * 0.1

    def loss(pp):
        with mesh:
            y, aux = M.moe_block(pp, x, cfg, jnp.float32, mesh)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0.0, name
