"""Gradient compression integrated into the real train step: training with
the int8 error-feedback transform must track uncompressed training."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import make_compressed_grad_transform
from repro.models.registry import build_model
from repro.optim import AdamW, warmup_cosine
from repro.training import make_train_step


def _run(steps, compressed):
    cfg = dataclasses.replace(get_config("granite-20b").smoke(), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(5e-3, 2, 100))
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (steps, 4, 32))

    if compressed:
        init_res, transform = make_compressed_grad_transform("int8")
        residuals = init_res(params)
        holder = {"res": residuals}

        def grad_transform(grads):
            out, holder["res"] = transform(grads, holder["res"])
            return out
    else:
        grad_transform = None

    step = jax.jit(make_train_step(model, opt, grad_accum=1)) if not compressed \
        else make_train_step(model, opt, grad_accum=1, grad_transform=grad_transform)
    losses = []
    for i in range(steps):
        batch = {"tokens": jnp.asarray(toks[i]), "labels": jnp.asarray(toks[i])}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_int8_compressed_training_tracks_uncompressed():
    plain = _run(10, compressed=False)
    comp = _run(10, compressed=True)
    assert np.isfinite(comp).all()
    # both runs must make progress and end within a small gap
    assert comp[-1] < comp[0]
    assert abs(comp[-1] - plain[-1]) < 0.15 * abs(plain[0]), (plain, comp)
