"""End-to-end behaviour tests for the paper's system.

These exercise the integrated stack: streaming clustering quality +
order-invariance (the paper's headline behaviours), sliding-window drift
tracking, train-loop convergence with checkpoint restart, and the
level-set recovery sanity check (Thm 3)."""

import numpy as np

from repro.core import (
    DynamicDBSCAN, EMZFixedCore, EMZRecompute, GridLSH,
    adjusted_rand_index,
)
from repro.data import blobs


def test_streaming_quality_matches_emz_and_beats_fixed_core():
    """Figure 2c in miniature: cluster-by-cluster arrival breaks the
    fixed-core ablation but not DynamicDBSCAN."""
    n = 4000
    X, y = blobs(n=n, d=8, n_clusters=6, cluster_std=0.2, seed=0)
    order = np.argsort(y, kind="stable")
    X, y = X[order], y[order]
    k, t, eps = 8, 8, 0.5
    lsh = GridLSH(8, eps, t, seed=0)
    dyn = DynamicDBSCAN(8, k, t, eps, lsh=lsh)
    fix = EMZFixedCore(8, k, t, eps, lsh=lsh)
    ids = []
    for s in range(0, n, 500):
        xb = X[s : s + 500]
        ids += [dyn.add_point(p) for p in xb]
        fix_labels = fix.add_batch(xb)
    lab = dyn.labels(ids)
    dyn_ari = adjusted_rand_index(y, np.array([lab[i] for i in ids]))
    fix_ari = adjusted_rand_index(y, fix_labels)
    assert dyn_ari > 0.9, dyn_ari
    assert fix_ari < 0.5, fix_ari


def test_deletion_workload_tracks_distribution_shift():
    """Sliding window over a drifting stream: after the drift, clusters
    must reflect only the live window."""
    rng = np.random.default_rng(1)
    phase1 = rng.normal(size=(800, 4)) * 0.1 + np.array([3, 3, 3, 3])
    phase2 = rng.normal(size=(800, 4)) * 0.1 - np.array([3, 3, 3, 3])
    dyn = DynamicDBSCAN(4, k=8, t=8, eps=0.5, seed=1)
    window = []
    for p in np.concatenate([phase1, phase2]):
        window.append(dyn.add_point(p))
        if len(window) > 800:
            dyn.delete_point(window.pop(0))
    labels = dyn.labels()
    live = [labels[i] for i in window]
    # all live points (phase 2) should be one cluster, few noise-labelled
    uniq = {v for v in live if v != -1}
    assert len(uniq) == 1
    assert sum(v == -1 for v in live) < 40
    dyn.check_invariants()


def test_level_set_recovery_sanity():
    """Thm 3 sanity: core points should lie in the high-density region
    (near cluster centres), not in the background noise."""
    rng = np.random.default_rng(2)
    dense = rng.normal(size=(3000, 3)) * 0.15          # high density blob
    sparse = rng.uniform(-8, 8, size=(300, 3))         # background
    dyn = DynamicDBSCAN(3, k=12, t=8, eps=0.4, seed=2)
    ids_dense = [dyn.add_point(p) for p in dense]
    ids_sparse = [dyn.add_point(p) for p in sparse]
    core_dense = np.mean([dyn.is_core(i) for i in ids_dense])
    far = [i for i, p in zip(ids_sparse, sparse) if np.linalg.norm(p) > 2.0]
    core_far = np.mean([dyn.is_core(i) for i in far])
    assert core_dense > 0.9, core_dense
    assert core_far < 0.05, core_far


def test_train_loop_converges_and_restarts(tmp_path):
    """Short train run must reduce loss; checkpoint-restart must resume."""
    from repro.launch.train import main as train_main

    losses = train_main([
        "--arch", "granite-20b", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "32", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ])
    assert np.mean(losses[-5:]) < np.mean(losses[:3]), losses
    # restart from the durable checkpoint and continue
    losses2 = train_main([
        "--arch", "granite-20b", "--smoke", "--steps", "32",
        "--batch", "4", "--seq", "32", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10", "--resume",
    ])
    assert len(losses2) == 2  # resumed at step 30, ran 2 more


def test_emz_and_dynamic_identical_partitions_on_stream():
    """System-level: with a shared LSH family the dynamic structure and the
    per-batch EMZ recompute agree on core partitions at every batch."""
    X, _ = blobs(n=1500, d=5, n_clusters=5, cluster_std=0.25, seed=3)
    k, t, eps = 8, 6, 0.5
    lsh = GridLSH(5, eps, t, seed=3)
    dyn = DynamicDBSCAN(5, k, t, eps, lsh=lsh)
    emz = EMZRecompute(5, k, t, eps, lsh=lsh)
    ids = []
    for s in range(0, 1500, 300):
        xb = X[s : s + 300]
        ids += [dyn.add_point(p) for p in xb]
        el = emz.add_batch(xb)
        dl = dyn.labels(ids)
        dyn_arr = np.array([dl[i] for i in ids])
        mask = dyn_arr >= 0
        assert adjusted_rand_index(dyn_arr[mask], el[mask]) > 0.999
