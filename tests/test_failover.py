"""Fault-tolerance tests (PR 8): TCP transport semantics (timeouts,
retries, token auth, exactly-once mutations), replicated shard lanes
with coordinator failover, the chaos harness, and partial fan-out
rollback.  The governing oracle is the same as PR 5's: whatever faults
are injected, a run that *reports* success must be bit-identical to the
fault-free in-process run of the same stream."""

import socket
import threading
import time

import numpy as np
import pytest

from repro.api import ClusterConfig, Insert, build_index
from repro.data import blobs
from repro.obs import Obs
from repro.service import (
    ChaosClient,
    HelloResp,
    LocalTransport,
    ProcessTransport,
    ShardUnavailableError,
    TcpTransport,
    connect_shards,
    decode,
    encode,
    read_frame,
    write_frame,
)

from test_service import cfg_for, interleaved_chunks


def inner_cfg(**kw):
    """A per-shard inner config, as a worker process would receive it."""
    base = dict(d=4, k=6, t=6, eps=0.45, seed=0, backend="dynamic")
    base.update(kw)
    return ClusterConfig(**base)


# ---------------------------------------------------------------------- #
# TCP transport: oracle, auth, dedup, deadlines
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("shards", [1, 2])
def test_tcp_transport_is_bit_identical_to_local(shards):
    chunks, _ = interleaved_chunks(n=150, d=4, seed=shards)
    loc = build_index(cfg_for(shards, "local"))
    tcp = build_index(cfg_for(shards, "tcp"))
    try:
        for chunk in chunks:
            assert loc.apply(chunk) == tcp.apply(chunk)
        assert tcp.labels() == loc.labels()
        tcp.check_invariants()
    finally:
        loc.close()
        tcp.close()


def test_tcp_auth_reject_is_permission_error_not_retried():
    good = TcpTransport(inner_cfg(), shard_id=0)
    try:
        t0 = time.perf_counter()
        with pytest.raises(PermissionError):
            TcpTransport(inner_cfg(), shard_id=0, addr=good._addr,
                         token="wrong-token")
        # a bad token will not heal: rejected on the handshake, no
        # backoff-retry loop (which would take >= 3 * BACKOFF_S)
        assert time.perf_counter() - t0 < TcpTransport.CONNECT_TIMEOUT_S
        # the worker survives an auth reject and keeps serving the
        # authenticated client
        assert good.ids() == []
    finally:
        good.close()


def test_tcp_mutation_dedup_is_exactly_once():
    import repro.service.messages as m

    X, _ = blobs(n=6, d=4, n_clusters=2, cluster_std=0.2, seed=0)
    t = TcpTransport(inner_cfg(), shard_id=0)
    try:
        req = m.InsertBatchReq(X=X, ids=list(range(6)), want_digest=False)
        first = t.request(req)
        # the transport stamped the mutation once; re-sending the same
        # stamped frame (what a post-reconnect retry does) must be
        # answered from the server's dedup cache, not applied twice
        assert req.op_seq is not None
        replay = t.request(req)
        assert list(replay.ids) == list(first.ids)
        assert replay.n_live == first.n_live
        assert sorted(t.ids()) == list(range(6))
    finally:
        t.close()


def test_tcp_retries_through_a_dropped_connection():
    X, _ = blobs(n=40, d=4, n_clusters=2, cluster_std=0.2, seed=1)
    loc = build_index(inner_cfg())
    t = TcpTransport(inner_cfg(), shard_id=0, obs=Obs())
    try:
        t.insert_batch(X[:20], ids=list(range(20)))
        loc.insert_batch(X[:20], ids=list(range(20)))
        t._sock.close()  # connection dies between requests
        t.insert_batch(X[20:], ids=list(range(20, 40)))
        loc.insert_batch(X[20:], ids=list(range(20, 40)))
        assert t.labels() == loc.labels()
        assert t._c_reconnects.value >= 1
    finally:
        t.close()
        loc.close()


def test_tcp_timeout_surfaces_with_retry_detail_within_deadline():
    """A server that accepts + authenticates but never answers requests
    must produce a ShardUnavailableError whose detail names the timeout
    and the retry count — within the configured deadline, never a hang."""
    srv = socket.create_server(("127.0.0.1", 0))
    stop = threading.Event()

    def black_hole():
        srv.settimeout(0.25)
        conns = []
        while not stop.is_set():
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            conns.append(conn)
            hello = decode(read_frame(conn))
            assert hello.kind == "hello"
            write_frame(conn, encode(HelloResp()))
            # ...and then read forever, answering nothing
        for c in conns:
            c.close()

    th = threading.Thread(target=black_hole, daemon=True)
    th.start()
    cfg = inner_cfg(rpc_timeout_s=0.2)
    t = TcpTransport(cfg, shard_id=0, addr=srv.getsockname(), token="x",
                     retries=1, obs=Obs())
    try:
        t0 = time.perf_counter()
        with pytest.raises(ShardUnavailableError) as ei:
            t.labels()
        elapsed = time.perf_counter() - t0
        detail = ei.value.args[0]
        assert "timed out" in detail and "retries" in detail
        # initial attempt + 1 retry, each bounded by rpc_timeout_s, plus
        # one backoff sleep and the reconnect handshake
        assert elapsed < 5.0
        assert t._c_retries.value >= 1
    finally:
        stop.set()
        th.join(timeout=5)
        t.close()
        srv.close()


def test_worker_die_after_crashes_on_schedule():
    # --die-after N: the worker serves N requests (hello included) and
    # exits hard before the next one — the crash knob the chaos harness
    # builds on.  The transport must fail fast, not retry a corpse.
    t = TcpTransport(inner_cfg(), shard_id=0, die_after=3)
    try:
        t.ids()  # request 2 (hello was 1)
        t0 = time.perf_counter()
        with pytest.raises(ShardUnavailableError, match="exited"):
            for _ in range(3):
                t.ids()
        assert time.perf_counter() - t0 < 10.0
    finally:
        t.close()


# ---------------------------------------------------------------------- #
# close() lifecycle
# ---------------------------------------------------------------------- #
def test_process_transport_close_is_idempotent_even_after_a_kill():
    clients = connect_shards(inner_cfg(), 2, "process")
    healthy, doomed = clients
    try:
        assert healthy.ids() == []
        doomed._proc.kill()
        doomed._proc.wait()
    finally:
        for c in clients:
            c.close()  # dead worker: escalation path, no exception
            c.close()  # second invocation is a no-op
    assert healthy._proc.poll() is not None
    with pytest.raises(ShardUnavailableError):
        healthy.ids()


# ---------------------------------------------------------------------- #
# replica lanes: deterministic replay, promotion, resync
# ---------------------------------------------------------------------- #
def test_replica_lane_is_bit_identical_to_local_oracle():
    chunks, _ = interleaved_chunks(n=150, d=4, seed=3)
    loc = build_index(cfg_for(2, "local", seed=3))
    rep = build_index(cfg_for(2, "process", seed=3, replicas=1))
    try:
        for chunk in chunks:
            assert loc.apply(chunk) == rep.apply(chunk)
        assert rep.labels() == loc.labels()
        # check_invariants on a replicated lane also byte-compares every
        # replica's snapshot against its primary's
        rep.check_invariants()
    finally:
        loc.close()
        rep.close()


def test_primary_kill_fails_over_with_oracle_identical_labels():
    chunks, _ = interleaved_chunks(n=160, d=4, seed=4)
    half = len(chunks) // 2
    loc = build_index(cfg_for(2, "local", seed=4))
    rep = build_index(cfg_for(2, "tcp", seed=4, replicas=1, obs=True))
    try:
        for chunk in chunks[:half]:
            assert loc.apply(chunk) == rep.apply(chunk)
        # SIGKILL shard 0's primary mid-stream: the lane must promote the
        # replica and keep answering, invisibly to the caller
        lane = rep.clients[0]
        lane._members[0].client._proc.kill()
        for chunk in chunks[half:]:
            assert loc.apply(chunk) == rep.apply(chunk)
        assert rep.labels() == loc.labels()
        rep.check_invariants()
        metrics = rep.obs.snapshot()["metrics"]
        assert metrics["failover.promotions"]["value"] >= 1
        # fleet counters exist in every instrumented snapshot, fired or not
        assert "rpc.retries" in metrics
        assert "failover.resyncs" in metrics
    finally:
        loc.close()
        rep.close()


def test_replicas_zero_kill_raises_fast_and_rolls_back():
    X, _ = blobs(n=120, d=4, n_clusters=2, cluster_std=0.2, seed=5)
    idx = build_index(cfg_for(2, "tcp", seed=5))
    try:
        idx.insert_batch(X[:80])
        idx.clients[0]._proc.kill()
        idx.clients[0]._proc.wait()
        before_next = idx._next_idx
        before_home = dict(idx._home)
        survivor_ids = sorted(idx.clients[1].ids())
        t0 = time.perf_counter()
        with pytest.raises(ShardUnavailableError, match="shard 0"):
            idx.insert_batch(X[80:])
        # fail fast (worker is a known corpse), never a hang
        assert time.perf_counter() - t0 < 10.0
        # the failed fan-out was rolled back: no half-applied batch
        assert idx._next_idx == before_next
        assert dict(idx._home) == before_home
        assert sorted(idx.clients[1].ids()) == survivor_ids
    finally:
        idx.close()  # idempotent, including the dead shard


# ---------------------------------------------------------------------- #
# chaos harness
# ---------------------------------------------------------------------- #
def test_partial_fanout_drop_rolls_back_then_recovers():
    """A transient one-shot failure on one shard mid-insert_batch leaves
    the coordinator's bridge/router state untouched; retrying the same
    batch then lands, and the end state matches the fault-free oracle."""
    X, _ = blobs(n=120, d=4, n_clusters=2, cluster_std=0.2, seed=7)
    oracle = build_index(cfg_for(2, "local", seed=7))
    idx = build_index(cfg_for(2, "local", seed=7))
    try:
        idx.insert_batch(X[:60])
        oracle.insert_batch(X[:60])
        n_before = len(idx)
        idx.clients[1] = ChaosClient(idx.clients[1], "drop",
                                     kinds=frozenset({"insert_batch"}))
        with pytest.raises(ShardUnavailableError, match="shard 1"):
            idx.insert_batch(X[60:])
        assert len(idx) == n_before
        idx.check_invariants()
        assert idx.labels() == oracle.labels()
        # the drop fired once (every=0): the retry goes through, and the
        # compensating rollback didn't poison the id space
        assert idx.insert_batch(X[60:]) == oracle.insert_batch(X[60:])
        assert idx.labels() == oracle.labels()
        assert idx.clients[1].injected == 1
    finally:
        idx.close()
        oracle.close()


def test_chaos_close_is_transparent_over_tcp():
    """Socket kills at the Nth request and every 2nd after: the TCP
    retry + dedup machinery must absorb all of them — same labels as the
    in-process engine, no double-applied mutations."""
    X, _ = blobs(n=60, d=4, n_clusters=2, cluster_std=0.2, seed=8)
    loc = build_index(inner_cfg())
    t = TcpTransport(inner_cfg(), shard_id=0)
    c = ChaosClient(t, "close", at=2, every=2)
    try:
        for i in range(0, 60, 10):
            ids = list(range(i, i + 10))
            c.insert_batch(X[i:i + 10], ids=ids)
            loc.insert_batch(X[i:i + 10], ids=ids)
        c.delete_batch(list(range(0, 20)))
        loc.delete_batch(list(range(0, 20)))
        assert c.labels() == loc.labels()
        assert sorted(c.ids()) == sorted(loc.ids())
        assert c.injected >= 2
    finally:
        c.close()
        loc.close()


def test_chaos_validates_its_knobs():
    local = LocalTransport(inner_cfg())
    try:
        with pytest.raises(ValueError, match="unknown chaos mode"):
            ChaosClient(local, "explode")
        with pytest.raises(ValueError, match="at must be"):
            ChaosClient(local, "drop", at=0)
        # close/corrupt operate on the socket; a socketless client can't
        with pytest.raises(ValueError, match="socket-backed"):
            ChaosClient(local, "close")
    finally:
        local.close()


# ---------------------------------------------------------------------- #
# config surface
# ---------------------------------------------------------------------- #
def test_config_validates_replicas_and_timeout_by_name():
    with pytest.raises(ValueError, match="replicas"):
        cfg_for(2, "tcp", replicas=-1)
    with pytest.raises(ValueError, match="rpc_timeout_s"):
        cfg_for(2, "tcp", rpc_timeout_s=0.0)
    cfg = cfg_for(2, "tcp", replicas=2, rpc_timeout_s=1.5)
    assert cfg.replicas == 2 and cfg.rpc_timeout_s == 1.5
