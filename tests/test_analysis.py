"""Tests for the repro.analysis static-analysis suite.

Each pass gets fixture-driven coverage: a known-bad fixture tree (every
seeded violation is flagged), a known-good twin (no findings), and a
suppression check (the same violation with an ``# analysis: allow[...]``
pragma is silent).  A meta-test runs ``python -m repro.analysis`` over
the real repo and requires a clean exit — the tree must stay
analysis-clean, and violations in new code fail CI through this test
even before the dedicated CI job runs.
"""

import abc
import json
import os
import subprocess
import sys
import textwrap
import types
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import cli
from repro.analysis.concurrency_pass import ConcurrencyGuards
from repro.analysis.fault_pass import FaultToleranceGuards
from repro.analysis.hotpath_pass import HotPathPurity
from repro.analysis.protocol_pass import ProtocolExhaustiveness
from repro.analysis.obs_pass import ObsDiscipline
from repro.analysis.registry_pass import RegistryConformance
from repro.analysis.walker import Project, SourceFile

REPO_ROOT = Path(__file__).resolve().parent.parent


def make_project(tmp_path, files):
    """Build a fixture tree: {relpath-under-repro: source} -> Project."""
    for rel, text in files.items():
        p = tmp_path / "repro" / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return Project(tmp_path)


def rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------- #
# walker: pragmas and parent links
# ---------------------------------------------------------------------- #
class TestWalker:
    def test_allow_pragma_same_line_and_line_above(self):
        sf = SourceFile(
            "x = 1  # analysis: allow[R1,R2]\n"
            "# analysis: allow[R3]\n"
            "y = 2\n"
            "z = 3\n", "m.py")
        assert sf.suppressed(1, "R1") and sf.suppressed(1, "R2")
        assert not sf.suppressed(1, "R9")
        assert sf.suppressed(3, "R3")  # pragma on the line above
        assert not sf.suppressed(4, "R3")  # does not leak downward

    def test_allow_star_suppresses_everything(self):
        sf = SourceFile("x = 1  # analysis: allow[*]\n", "m.py")
        assert sf.suppressed(1, "ANY999")

    def test_hot_path_pragma_positions(self):
        sf = SourceFile(textwrap.dedent("""\
            # hot-path
            def above():
                pass

            def trailing():  # hot-path
                pass

            def cold():
                pass
            """), "m.py")
        fns = {f.name: f for f in sf.functions()}
        assert sf.is_hot_path(fns["above"])
        assert sf.is_hot_path(fns["trailing"])
        assert not sf.is_hot_path(fns["cold"])

    def test_project_skips_unparseable(self, tmp_path):
        project = make_project(tmp_path, {"ok.py": "x = 1\n",
                                          "bad.py": "def broken(:\n"})
        assert [sf.rel for sf in project.sources()] == ["ok.py"]


# ---------------------------------------------------------------------- #
# hot-path purity
# ---------------------------------------------------------------------- #
BAD_KERNEL = """\
    import numpy as np

    def kernel(x, acc):
        for i in range(8):
            acc = acc + x
        y = np.asarray(acc)
        return float(y)
"""

BAD_HOT = """\
    import numpy as np

    # hot-path
    def resolve(ids, pts):
        out = []
        for i in ids:
            v = np.asarray(pts[i])
            out.append({"id": i, "v": v})
        return out
"""


class TestHotPathPurity:
    def test_device_scope_flags_loop_sync_and_numpy(self, tmp_path):
        project = make_project(tmp_path, {"kernels/bad.py": BAD_KERNEL})
        found = rules(HotPathPurity().run(project))
        assert found == ["HOT001", "HOT002", "HOT003"]

    def test_device_scope_clean_kernel(self, tmp_path):
        project = make_project(tmp_path, {"kernels/ok.py": """\
            import jax.numpy as jnp

            def kernel(x):
                return jnp.sum(x * x)
        """})
        assert HotPathPurity().run(project) == []

    def test_hot_pragma_flags_per_element_work(self, tmp_path):
        project = make_project(tmp_path, {"shard/hot.py": BAD_HOT})
        found = rules(HotPathPurity().run(project))
        assert found == ["HOT101", "HOT103"]

    def test_unmarked_function_is_not_checked(self, tmp_path):
        project = make_project(
            tmp_path, {"shard/cold.py": BAD_HOT.replace("# hot-path", "")})
        assert HotPathPurity().run(project) == []

    def test_suppression_pragma(self, tmp_path):
        src = BAD_HOT.replace(
            "v = np.asarray(pts[i])",
            "v = np.asarray(pts[i])  # analysis: allow[HOT101]")
        project = make_project(tmp_path, {"shard/hot.py": src})
        assert rules(HotPathPurity().run(project)) == ["HOT103"]


# ---------------------------------------------------------------------- #
# concurrency guards
# ---------------------------------------------------------------------- #
BAD_FANOUT = """\
    class Coordinator:
        def insert(self, s, X):
            self._fanout({
                s: (lambda s=s: self.bridge.insert(s)),
            })

        def rehome(self, s, i):
            self.pool.submit(lambda: self._assign(i))

        def _assign(self, i):
            pass

    def _mk(self):
        return lambda i=0: self.clients[i].insert_batch([])
"""


class TestConcurrencyGuards:
    def test_owned_mutation_in_fanout_lambda(self, tmp_path):
        project = make_project(tmp_path, {"shard/index.py": BAD_FANOUT})
        found = ConcurrencyGuards().run(project)
        assert rules(found) == ["CONC001"]
        assert "bridge" in found[0].message

    def test_self_write_in_submitted_lambda(self, tmp_path):
        src = BAD_FANOUT.replace("self._assign(i)", "self._home.update({})") \
                        .replace("self.bridge.insert(s)", "s")
        src = src.replace("lambda: self._home.update({})",
                          "lambda: self._tick()")  # calls alone are fine
        project = make_project(tmp_path, {"shard/index.py": src})
        assert ConcurrencyGuards().run(project) == []

    def test_self_subscript_write_in_fanout(self, tmp_path):
        project = make_project(tmp_path, {"shard/index.py": """\
            class C:
                def go(self, s):
                    self._fanout({s: (lambda s=s: self._home.__setitem__(0, s))})
                    self.pool.submit(lambda: exec("self._cache = None"))
        """})
        # dunder/exec tricks are out of scope; the AST form is:
        project2 = make_project(tmp_path / "b", {"shard/index.py": """\
            class C:
                def go(self, s):
                    def work():
                        self._cache = None
                    self.pool.submit(lambda: work())
        """})
        assert ConcurrencyGuards().run(project) == []
        # the write sits in a local def, not the submitted lambda — the
        # pass checks submitted callables only (the repo idiom)
        assert ConcurrencyGuards().run(project2) == []

    def test_fanout_reads_are_allowed(self, tmp_path):
        project = make_project(tmp_path, {"shard/index.py": """\
            class C:
                def labels(self, ids):
                    return self._fanout({
                        0: (lambda: self.clients[0].labels(ids)),
                        1: (lambda: self.bridge.lookup(ids)),
                    })
        """})
        assert ConcurrencyGuards().run(project) == []

    def test_bare_except_and_unchained_raise(self, tmp_path):
        project = make_project(tmp_path, {"service/transport.py": """\
            def request(sock):
                try:
                    return sock.recv(1)
                except:
                    raise RuntimeError("boom")
        """})
        assert rules(ConcurrencyGuards().run(project)) == \
            ["CONC002", "CONC003"]

    def test_chained_and_reraise_are_clean(self, tmp_path):
        project = make_project(tmp_path, {"service/transport.py": """\
            def request(sock):
                try:
                    return sock.recv(1)
                except OSError as e:
                    if transient(e):
                        raise e
                    raise RuntimeError("closed") from e
                except KeyError:
                    raise ValueError("no shard") from None
        """})
        assert ConcurrencyGuards().run(project) == []

    def test_error_rules_scoped_to_protocol_modules(self, tmp_path):
        project = make_project(tmp_path, {"core/engine.py": """\
            def load(d):
                try:
                    return d["k"]
                except KeyError:
                    raise ValueError("bad state")
        """})
        assert ConcurrencyGuards().run(project) == []


# ---------------------------------------------------------------------- #
# protocol exhaustiveness
# ---------------------------------------------------------------------- #
FIXTURE_MESSAGES = """\
    import dataclasses
    from typing import Any, ClassVar, Dict, Optional, Tuple, Type

    import numpy as np

    MESSAGE_TYPES: Dict[str, type] = {}


    def register_message(cls):
        MESSAGE_TYPES[cls.kind] = cls
        return cls


    @dataclasses.dataclass
    class Message:
        kind: ClassVar[str] = ""
        _dtypes: ClassVar[Dict[str, Any]] = {}
        _poly_dtypes: ClassVar[Dict[str, Tuple[Any, ...]]] = {}
        _array_dicts: ClassVar[Tuple[str, ...]] = ()


    @register_message
    @dataclasses.dataclass
    class PingReq(Message):
        kind = "ping"
        _dtypes = {"ids": np.int64}
        ids: Optional[np.ndarray] = None


    @register_message
    @dataclasses.dataclass
    class PingResp(Message):
        kind = "ping_resp"
        n: int = 0


    @register_message
    @dataclasses.dataclass
    class OkResp(Message):
        kind = "ok"


    @dataclasses.dataclass
    class LostResp(Message):  # not registered -> PROTO001
        kind = "lost"


    @register_message
    @dataclasses.dataclass
    class BlobReq(Message):  # payload without dtype -> PROTO002
        kind = "blob"
        data: Optional[np.ndarray] = None


    @register_message
    @dataclasses.dataclass
    class TagsReq(Message):  # object dtype -> PROTO003
        kind = "tags"
        _dtypes = {"tags": np.object_}
        tags: Optional[np.ndarray] = None


    @register_message
    @dataclasses.dataclass
    class OrphanReq(Message):  # no dispatch entry -> PROTO004
        kind = "orphan"
"""

FIXTURE_SERVICE = """\
    from . import messages as m


    class FixtureService:
        def __init__(self, index):
            self.index = index
            self._dispatch = {
                m.PingReq: self._ping,
                m.BlobReq: lambda req: m.OkResp(),
                m.TagsReq: self._tags,
            }

        def _ping(self, req) -> m.OkResp:  # bypasses PingResp -> PROTO006
            return m.OkResp()

        def _tags(self, req):  # no resolvable response -> PROTO005
            return self.index.tags(req)
"""


def load_fixture_module(path: Path, name: str) -> types.ModuleType:
    mod = types.ModuleType(name)
    exec(compile(path.read_text(), str(path), "exec"), mod.__dict__)
    return mod


class TestProtocolExhaustiveness:
    @pytest.fixture
    def fixture_project(self, tmp_path):
        project = make_project(tmp_path, {
            "service/messages.py": FIXTURE_MESSAGES,
            "service/service.py": FIXTURE_SERVICE,
        })
        mod = load_fixture_module(
            tmp_path / "repro" / "service" / "messages.py",
            "fixture_messages")
        return project, mod

    def test_all_rules_fire_on_seeded_fixture(self, fixture_project):
        project, mod = fixture_project
        found = ProtocolExhaustiveness(
            messages=mod, service_class="FixtureService").run(project)
        assert rules(found) == ["PROTO001", "PROTO002", "PROTO003",
                                "PROTO004", "PROTO005", "PROTO006"]
        by_rule = {f.rule: f for f in found}
        assert "LostResp" in by_rule["PROTO001"].message
        assert "BlobReq.data" in by_rule["PROTO002"].message
        assert "TagsReq.tags" in by_rule["PROTO003"].message
        assert "OrphanReq" in by_rule["PROTO004"].message
        assert "PingResp" in by_rule["PROTO006"].message
        # findings anchor to class definition lines in the fixture source
        assert all(f.path.endswith(".py") and f.line > 0 for f in found)

    def test_real_protocol_is_clean(self):
        found = ProtocolExhaustiveness().run(Project.locate())
        assert found == []

    def test_poly_dtypes_accepted_object_dtype_rejected(self):
        from repro.service import messages as m
        resp = m.InsertBatchResp(
            ids=np.arange(3), digest=np.zeros((3, 2, 2), dtype=np.int32))
        assert resp.digest.dtype == np.int32
        with pytest.raises(TypeError, match="dtype"):
            m.InsertBatchResp(ids=np.arange(1),
                              digest=np.array([object()], dtype=object))

    def test_codec_refuses_object_arrays(self):
        from repro.service import codec
        from repro.service import messages as m
        snap = m.SnapshotResp(state={"k": np.array([{}], dtype=object)})
        with pytest.raises(TypeError, match="non-fixed dtype"):
            codec.encode(snap)


# ---------------------------------------------------------------------- #
# registry conformance
# ---------------------------------------------------------------------- #
class FixtureBase(abc.ABC):
    native_component_queries = False

    @abc.abstractmethod
    def insert(self, x):
        ...

    def core_anchor_of(self, idx):
        raise NotImplementedError

    def _state(self):
        return {}

    def _load_state(self, state):
        pass

    def snapshot(self):
        return {"state": self._state()}

    def restore(self, snap):
        self._load_state(snap["state"])


class GoodBackend(FixtureBase):
    native_component_queries = True

    def insert(self, x):
        return 0

    def core_anchor_of(self, idx):
        return idx

    def _state(self):
        return {"n": np.zeros(1)}

    def _load_state(self, state):
        pass


class StillAbstract(FixtureBase):  # REG001
    pass


class HalfPersistent(FixtureBase):  # REG002
    def insert(self, x):
        return 0

    def _state(self):
        return {"n": np.zeros(1)}


class FlagWithoutAnchor(FixtureBase):  # REG003
    native_component_queries = True

    def insert(self, x):
        return 0


class AnchorWithoutFlag(FixtureBase):  # REG004 (never mentions the flag)
    def insert(self, x):
        return 0

    def core_anchor_of(self, idx):
        return idx


class TestRegistryConformance:
    def run_on(self, tmp_path, *classes):
        project = make_project(tmp_path, {"__init__.py": ""})
        return RegistryConformance(
            classes=classes, base=FixtureBase).run(project)

    def test_good_backend_is_clean(self, tmp_path):
        assert self.run_on(tmp_path, GoodBackend) == []

    def test_each_seeded_violation(self, tmp_path):
        cases = [(StillAbstract, "REG001"), (HalfPersistent, "REG002"),
                 (FlagWithoutAnchor, "REG003"), (AnchorWithoutFlag, "REG004")]
        for cls, rule in cases:
            found = self.run_on(tmp_path, cls)
            assert rules(found) == [rule], (cls.__name__, rules(found))
            assert cls.__name__ in found[0].message

    def test_real_registry_is_clean(self):
        assert RegistryConformance().run(Project.locate()) == []

    def test_real_backends_in_closure(self):
        from repro.api.index import ClusterIndex
        from repro.analysis.registry_pass import _subclass_closure
        import repro.shard  # noqa: F401 — registers the sharded backend
        import repro.tiered  # noqa: F401 — registers the tiered backend

        names = {c.__name__ for c in _subclass_closure(ClusterIndex)}
        assert {"EulerTourIndex", "RecomputeIndex", "ShardedIndex",
                "ApproxIndex", "TieredIndex"} <= names

    def test_tiered_backends_conform(self, tmp_path):
        # the sampled tier's index classes pass the same conformance
        # rules as the seeded-good fixture — pinned here directly so a
        # regression names the class, not just "registry not clean"
        from repro.api.backends import ApproxIndex
        from repro.api.index import ClusterIndex
        from repro.tiered import TieredIndex

        project = make_project(tmp_path, {"__init__.py": ""})
        found = RegistryConformance(
            classes=(ApproxIndex, TieredIndex),
            base=ClusterIndex).run(project)
        assert found == [], rules(found)


# ---------------------------------------------------------------------- #
# CLI
# ---------------------------------------------------------------------- #
class TestCli:
    def test_exit_1_and_text_report_on_findings(self, tmp_path, capsys):
        make_project(tmp_path, {"kernels/bad.py": BAD_KERNEL})
        rc = cli.main(["--root", str(tmp_path),
                       "--select", "hot-path-purity"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "kernels/bad.py" in out and "HOT001" in out

    def test_exit_0_and_json_on_clean_tree(self, tmp_path, capsys):
        make_project(tmp_path, {"core/ok.py": "x = 1\n"})
        rc = cli.main(["--root", str(tmp_path), "--json",
                       "--select", "hot-path-purity,concurrency-guards"])
        report = json.loads(capsys.readouterr().out)
        assert rc == 0
        assert report["ok"] and report["n_findings"] == 0

    def test_json_report_shape(self, tmp_path, capsys):
        make_project(tmp_path, {"kernels/bad.py": BAD_KERNEL})
        cli.main(["--root", str(tmp_path), "--json",
                  "--select", "hot-path-purity"])
        report = json.loads(capsys.readouterr().out)
        assert not report["ok"]
        assert report["counts"] == {"hot-path-purity": report["n_findings"]}
        f = report["findings"][0]
        assert set(f) == {"pass_name", "rule", "path", "line", "message"}

    def test_unknown_pass_is_usage_error(self, tmp_path, capsys):
        rc = cli.main(["--root", str(tmp_path), "--select", "nope"])
        assert rc == 2
        assert "unknown pass" in capsys.readouterr().err

    def test_list_passes(self, capsys):
        assert cli.main(["--list"]) == 0
        out = capsys.readouterr().out
        for name in ("protocol-exhaustiveness", "hot-path-purity",
                     "concurrency-guards", "registry-conformance",
                     "obs-discipline"):
            assert name in out


# ---------------------------------------------------------------------- #
# obs discipline
# ---------------------------------------------------------------------- #
class TestObsDiscipline:
    def test_open_coded_span_and_timer_are_flagged(self, tmp_path):
        project = make_project(tmp_path, {"service/handler.py": """\
            class Svc:
                def handle(self, req):
                    sp = self.obs.tracer.span("shard.op")  # stored, leaks
                    sp.__enter__()
                    t = self.h.timer()
                    return req
        """})
        assert rules(ObsDiscipline().run(project)) == ["OBS001", "OBS001"]

    def test_with_statement_items_are_clean(self, tmp_path):
        project = make_project(tmp_path, {"shard/coord.py": """\
            class Coord:
                def insert(self, X):
                    with self.obs.tracer.span("coord.insert", n=len(X)), \\
                            self._h_insert_us.timer():
                        return self._impl(X)

                def merge(self):
                    with self.obs.tracer.span("bridge.merge"):
                        with self._h_merge_us.timer():
                            return self._merge_impl()
        """})
        assert ObsDiscipline().run(project) == []

    def test_scope_is_service_and_shard_only(self, tmp_path):
        # an unrelated .timer() API outside the protocol modules is fine
        project = make_project(tmp_path, {"serving/loop.py": """\
            def tick(clock):
                t = clock.timer()
                return t.elapsed()
        """})
        assert ObsDiscipline().run(project) == []

    def test_suppression_pragma(self, tmp_path):
        project = make_project(tmp_path, {"service/handler.py": """\
            def probe(h):
                t = h.timer()  # analysis: allow[OBS001]
                return t
        """})
        assert ObsDiscipline().run(project) == []


class TestFaultToleranceGuards:
    def test_swallowed_shard_unavailable_is_flagged(self, tmp_path):
        project = make_project(tmp_path, {"shard/index.py": """\
            def fanout(clients, req):
                out = []
                for c in clients:
                    try:
                        out.append(c.request(req))
                    except ShardUnavailableError:
                        out.append(None)  # dead shard -> wrong answers
                return out
        """})
        assert rules(FaultToleranceGuards().run(project)) == ["FT001"]

    def test_reraise_and_failover_path_are_clean(self, tmp_path):
        project = make_project(tmp_path, {"service/replica.py": """\
            class Lane:
                def mutate(self, req):
                    try:
                        return self.primary.request(req)
                    except ShardUnavailableError:
                        self._fail_member(self.primary)  # promote + evict
                        return self.primary.request(req)

                def query(self, req):
                    try:
                        return self.primary.request(req)
                    except ShardUnavailableError as e:
                        raise RuntimeError("lane dead") from e
        """})
        assert FaultToleranceGuards().run(project) == []

    def test_tuple_clause_and_dotted_name_are_matched(self, tmp_path):
        project = make_project(tmp_path, {"service/transport.py": """\
            def roundtrip(sock, req):
                try:
                    return exchange(sock, req)
                except (OSError, transport.ShardUnavailableError):
                    return None
        """})
        assert rules(FaultToleranceGuards().run(project)) == ["FT001"]

    def test_nested_handler_does_not_vouch_for_outer(self, tmp_path):
        # the inner OSError handler raises, but the *outer*
        # ShardUnavailableError body still swallows
        project = make_project(tmp_path, {"shard/router.py": """\
            def route(c, req):
                try:
                    return c.request(req)
                except ShardUnavailableError:
                    try:
                        c.close()
                    except OSError:
                        raise
                    return None
        """})
        # the close() try/except raising still counts as the outer body
        # raising only if it is in the outer body — it is nested, and its
        # Raise belongs to the inner handler, so FT001 fires
        assert rules(FaultToleranceGuards().run(project)) == ["FT001"]

    def test_scope_is_service_and_shard_only(self, tmp_path):
        project = make_project(tmp_path, {"serving/engine.py": """\
            def submit(c, req):
                try:
                    return c.request(req)
                except ShardUnavailableError:
                    return None  # benchmarks/serving may degrade
        """})
        assert FaultToleranceGuards().run(project) == []

    def test_suppression_pragma(self, tmp_path):
        project = make_project(tmp_path, {"shard/index.py": """\
            def rollback(survivors, ids):
                for c in survivors:
                    try:
                        c.delete_batch(ids)
                    except ShardUnavailableError:  # analysis: allow[FT001]
                        pass  # double failure: counter is the record
        """})
        assert FaultToleranceGuards().run(project) == []


# ---------------------------------------------------------------------- #
# the repo itself stays clean
# ---------------------------------------------------------------------- #
def test_repo_is_analysis_clean():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout
