"""Unit tests for the HLO analyzer: trip-count multipliers, dot flops,
collective byte accounting — the foundation of the roofline numbers."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo

SAMPLE = """
HloModule jit_f, entry_computation_layout={(f32[5,16,32])->f32[]}

%body (param: (s32[], f32[2,32], f32[5,16,32])) -> (s32[], f32[2,32], f32[5,16,32]) {
  %param = (s32[], f32[2,32]{1,0}, f32[5,16,32]{2,1,0}) parameter(0)
  %gte = f32[2,64]{0,1} get-tuple-element(%param), index=1
  %ag = f32[2,64]{0,1} all-gather(%gte), channel_id=1, replica_groups=[4,2]<=[8], dimensions={1}
  %w = f32[64,32]{1,0} get-tuple-element(%param), index=2
  %dot = f32[2,32]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %tuple = (s32[], f32[2,32]{1,0}, f32[5,16,32]{2,1,0}) tuple(%dot, %dot, %w)
}

%cond (p: (s32[], f32[2,32], f32[5,16,32])) -> pred[] {
  %p = (s32[], f32[2,32]{1,0}, f32[5,16,32]{2,1,0}) parameter(0)
  %c = s32[] constant(5)
  %g = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%g, %c), direction=LT
}

ENTRY %main (arg: f32[5,16,32]) -> f32[] {
  %arg = f32[5,16,32]{2,1,0} parameter(0)
  %init = (s32[], f32[2,32]{1,0}, f32[5,16,32]{2,1,0}) tuple(%arg, %arg, %arg)
  %while = (s32[], f32[2,32]{1,0}, f32[5,16,32]{2,1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %out = f32[2,32]{1,0} get-tuple-element(%while), index=1
  %ar = f32[] all-reduce(%out), channel_id=3, replica_groups=[4,2]<=[8], to_apply=%cond
  ROOT %r = f32[] get-tuple-element(%ar)
}
"""


def test_parse_structure():
    comps, entry = parse_hlo(SAMPLE)
    assert entry == "main"
    assert set(comps) >= {"body", "cond", "main"}
    assert any(op.kind == "while" for op in comps["main"].ops)


def test_trip_count_multiplies_body_metrics():
    m = analyze(SAMPLE)
    # dot: 2*2*32*64 flops per iter × 5 iterations
    assert m.flops == pytest.approx(5 * 2 * 2 * 32 * 64)
    # all-gather inside body: result f32[2,64] = 512 B, group 2 ⇒
    # wire = 512*(2-1)/2 = 256 per iter × 5; all-reduce f32[] ≈ 4 B
    assert m.per_collective["all-gather"] == pytest.approx(5 * 256)
    assert m.per_collective["all-reduce"] == pytest.approx(2 * 4 * 0.5)


def test_real_compiled_module_loop_accounting():
    """End-to-end: 7-step scan of an (8×16)·(16×4) matmul on 1 device."""
    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((7, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    txt = jax.jit(f).lower(w, x).compile().as_text()
    m = analyze(txt)
    expect = 7 * 2 * 8 * 16 * 16
    assert m.flops == pytest.approx(expect, rel=0.01)
    assert m.collective_bytes == 0.0
    assert m.hbm_bytes > 7 * (8 * 16 * 4)  # at least the activations


def test_grad_flops_roughly_triple_forward():
    def fwd(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    w = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    f_txt = jax.jit(fwd).lower(w, x).compile().as_text()
    g_txt = jax.jit(jax.grad(fwd)).lower(w, x).compile().as_text()
    f_fl = analyze(f_txt).flops
    g_fl = analyze(g_txt).flops
    assert 2.0 <= g_fl / f_fl <= 4.5, (f_fl, g_fl)
