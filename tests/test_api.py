"""Tests for the unified repro.api surface: backend registry, mixed
Insert/Delete streams, cross-backend partition equivalence, and
snapshot/restore round-trips (in memory and through CheckpointManager)."""

import numpy as np
import pytest

from repro.api import (
    NOISE,
    ClusterConfig,
    Delete,
    Insert,
    available_backends,
    build_index,
    restore_index,
)
from repro.data import blobs

DYNAMIC_BACKENDS = ("dynamic", "batched", "batched-device")
ALL_BACKENDS = available_backends()


def _bijective(la, lb) -> bool:
    for u, v in ((la, lb), (lb, la)):
        seen = {}
        for a, b in zip(u, v):
            if seen.setdefault(a, b) != b:
                return False
    return True


def assert_same_partition(A: dict, B: dict):
    """Same live ids, same noise set, same partition up to label renaming."""
    assert sorted(A) == sorted(B)
    ids = sorted(A)
    la = np.array([A[i] for i in ids])
    lb = np.array([B[i] for i in ids])
    assert np.array_equal(la == NOISE, lb == NOISE)
    mask = la != NOISE
    assert _bijective(la[mask], lb[mask])


def mixed_stream(n=400, d=4, seed=0, p_delete=0.25):
    """Deterministic mixed Insert/Delete event stream (auto-assigned ids)."""
    X, _ = blobs(n=n, d=d, n_clusters=4, cluster_std=0.15, seed=seed)
    rng = np.random.default_rng(seed)
    events, alive, nxt = [], [], 0
    for j in range(n):
        events.append(Insert(X[j]))
        alive.append(nxt)
        nxt += 1
        if rng.random() < p_delete and len(alive) > 10:
            events.append(Delete(alive.pop(int(rng.integers(len(alive))))))
    return events


# ---------------------------------------------------------------------- #
# registry / config
# ---------------------------------------------------------------------- #
def test_registry_exposes_required_backends():
    for required in ("dynamic", "batched", "batched-device", "emz-static",
                     "naive", "sharded"):
        assert required in ALL_BACKENDS


def test_register_backend_overwrite_and_unregister():
    from repro.api import register_backend, unregister_backend

    @register_backend("swap-me")
    def _a(cfg):
        return build_index(cfg.replace(backend="dynamic"))

    with pytest.raises(ValueError, match="already registered"):
        register_backend("swap-me")(_a)

    @register_backend("swap-me", overwrite=True)
    def _b(cfg):
        return build_index(cfg.replace(backend="batched"))

    index = build_index(ClusterConfig(d=2, k=2, t=2, eps=0.5,
                                      backend="swap-me"))
    from repro.core.batched import BatchedDynamicDBSCAN
    assert isinstance(index.engine, BatchedDynamicDBSCAN)
    unregister_backend("swap-me")
    assert "swap-me" not in available_backends()
    with pytest.raises(KeyError, match="swap-me"):
        unregister_backend("swap-me")


def test_unknown_backend_raises_with_listing():
    with pytest.raises(KeyError, match="dynamic"):
        build_index(ClusterConfig(d=2, k=2, t=2, eps=0.5, backend="nope"))


@pytest.mark.parametrize("bad,named", [
    (dict(d=0, k=2, t=2, eps=0.5), "d"),
    (dict(d=2, k=0, t=2, eps=0.5), "k"),
    (dict(d=2, k=2, t=0, eps=0.5), "t"),
    (dict(d=2, k=2, t=2, eps=-1.0), "eps"),
    (dict(d=2, k=2, t=2, eps=0.0), "eps"),
    (dict(d=2, k=2, t=2, eps=0.5, repair="sloppy"), "repair"),
    (dict(d=2, k=2, t=2, eps=0.5, shards=0), "shards"),
    (dict(d=2, k=2, t=2, eps=0.5, inner_backend="sharded"), "inner_backend"),
])
def test_config_validation(bad, named):
    """Bad parameters fail at construction, naming the parameter, instead
    of failing deep inside GridLSH.__init__."""
    with pytest.raises(ValueError, match=named):
        ClusterConfig(**bad)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_build_index_works_for_every_backend(backend):
    X, _ = blobs(n=200, d=3, n_clusters=3, cluster_std=0.15, seed=0)
    index = build_index(ClusterConfig(d=3, k=5, t=5, eps=0.4, seed=0,
                                      backend=backend))
    ids = index.insert_batch(X)
    assert len(index) == 200 and ids[0] in index
    assert index.ids() == sorted(ids)
    lab = index.labels()
    assert set(lab) == set(ids)
    # label() agrees with labels() on cluster co-membership
    a, b = ids[0], ids[1]
    if lab[a] != NOISE and lab[b] != NOISE:
        assert (index.label(a) == index.label(b)) == (lab[a] == lab[b])
    index.check_invariants()


# ---------------------------------------------------------------------- #
# mutation semantics
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ("dynamic", "batched", "emz-static"))
def test_explicit_indices_and_duplicates(backend):
    X, _ = blobs(n=20, d=3, n_clusters=2, seed=1)
    index = build_index(ClusterConfig(d=3, k=3, t=3, eps=0.5,
                                      backend=backend))
    assert index.insert(X[0], idx=17) == 17
    with pytest.raises(KeyError):
        index.insert(X[1], idx=17)
    # auto-assignment continues past pinned ids
    assert index.insert_batch(X[1:4], ids=[None, 99, None]) == [18, 99, 100]
    with pytest.raises(KeyError):
        index.delete(12345)


@pytest.mark.parametrize("backend", ("dynamic", "batched", "emz-static",
                                     "sharded"))
def test_delete_batch_rejects_duplicate_ids(backend):
    X, _ = blobs(n=30, d=3, n_clusters=2, seed=4)
    index = build_index(ClusterConfig(d=3, k=3, t=3, eps=0.5,
                                      backend=backend))
    ids = index.insert_batch(X)
    with pytest.raises(KeyError, match=f"duplicate id {ids[7]}"):
        index.delete_batch([ids[2], ids[7], ids[7]])
    # nothing was deleted before the duplicate was detected
    assert len(index) == 30
    index.delete_batch(ids[:5])
    assert len(index) == 25


def test_engine_level_delete_batch_rejects_duplicates():
    from repro.core.batched import BatchedDynamicDBSCAN

    eng = BatchedDynamicDBSCAN(3, 3, 3, 0.5, seed=0)
    ids = eng.add_batch(np.zeros((4, 3)) + np.arange(4)[:, None])
    with pytest.raises(KeyError, match="duplicate id"):
        eng.delete_batch([ids[0], ids[0]])
    assert len(eng.points) == 4


@pytest.mark.parametrize("backend", ("dynamic", "batched"))
def test_apply_mixed_stream_returns_handles(backend):
    X, _ = blobs(n=30, d=3, n_clusters=2, seed=2)
    index = build_index(ClusterConfig(d=3, k=3, t=3, eps=0.5,
                                      backend=backend))
    out = index.apply([
        Insert(X[0]), Insert(X[1], idx=50), Delete(50),
        Insert(X[2]), Delete(0),
    ])
    assert out == [0, 50, None, 51, None]
    assert index.ids() == [51]
    index.check_invariants()


@pytest.mark.parametrize("backend", ("dynamic", "batched", "emz-static"))
def test_wrong_dimension_point_rejected(backend):
    index = build_index(ClusterConfig(d=2, k=2, t=2, eps=0.5,
                                      backend=backend))
    with pytest.raises(ValueError, match="shape"):
        index.insert(np.zeros(5))
    with pytest.raises(ValueError, match="shape"):
        index.insert_batch(np.zeros((3, 4)))


def test_apply_rejects_non_events():
    index = build_index(ClusterConfig(d=2, k=2, t=2, eps=0.5))
    with pytest.raises(TypeError):
        index.apply([("add", [0.0, 0.0])])


# ---------------------------------------------------------------------- #
# cross-backend equivalence
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_insert_stream_equivalent_across_backends(seed):
    """Same insert stream ⇒ same partition (up to label permutation)
    across the dynamic engines and both recompute baselines."""
    X, _ = blobs(n=350, d=4, n_clusters=4, cluster_std=0.15, seed=seed)
    cfg = ClusterConfig(d=4, k=8, t=8, eps=0.45, seed=seed)
    ref = None
    for backend in ("dynamic", "batched", "emz-static", "naive"):
        index = build_index(cfg.replace(backend=backend))
        index.insert_batch(X)
        lab = index.labels()
        if ref is None:
            ref = lab
        else:
            assert_same_partition(ref, lab)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_mixed_stream_equivalent_across_backends(seed):
    """Same mixed Insert/Delete stream ⇒ same partition across
    "dynamic"/"batched"/"naive" (ISSUE acceptance) + "emz-static"."""
    events = mixed_stream(n=400, d=4, seed=seed)
    ref = None
    cfg = ClusterConfig(d=4, k=8, t=8, eps=0.45, seed=seed)
    for backend in ("dynamic", "batched", "naive", "emz-static"):
        index = build_index(cfg.replace(backend=backend))
        index.apply(events)
        index.check_invariants()
        lab = index.labels()
        if ref is None:
            ref = lab
        else:
            assert_same_partition(ref, lab)


# ---------------------------------------------------------------------- #
# snapshot / restore
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ("dynamic", "batched", "emz-static",
                                     "naive"))
def test_snapshot_restore_roundtrip_1k_updates(backend):
    """Acceptance criterion: snapshot()/restore() preserves
    check_invariants() and cluster labels on a 1k-update workload."""
    events = mixed_stream(n=800, d=4, seed=3, p_delete=0.3)
    assert len(events) > 1000
    index = build_index(ClusterConfig(d=4, k=8, t=8, eps=0.45, seed=3,
                                      backend=backend))
    index.apply(events)
    restored = restore_index(index.snapshot())
    restored.check_invariants()
    assert restored.labels() == index.labels()
    assert restored.ids() == index.ids()
    # restored index stays live: new updates land on fresh handles
    new = restored.insert(np.zeros(4))
    assert new not in index
    restored.delete(new)
    assert restored.labels() == index.labels()


def test_snapshot_restore_preserves_exact_forest():
    """The dynamic snapshot stores the spanning forest explicitly, so the
    restored structure matches edge-for-edge (not just up to partition)."""
    events = mixed_stream(n=300, d=3, seed=5)
    index = build_index(ClusterConfig(d=3, k=6, t=6, eps=0.5, seed=5))
    index.apply(events)
    restored = restore_index(index.snapshot())
    assert (sorted(index.engine.forest._edge)
            == sorted(restored.engine.forest._edge))
    assert index.engine.support == restored.engine.support
    assert index.engine.attach == restored.engine.attach


def test_restore_refuses_config_mismatch_and_non_empty():
    index = build_index(ClusterConfig(d=3, k=4, t=4, eps=0.5))
    index.insert(np.zeros(3))
    snap = index.snapshot()
    other = build_index(ClusterConfig(d=3, k=5, t=4, eps=0.5))
    with pytest.raises(ValueError, match="config"):
        other.restore(snap)
    with pytest.raises(ValueError, match="empty"):
        index.restore(snap)


def test_checkpoint_manager_index_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    events = mixed_stream(n=300, d=4, seed=7)
    index = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.5, seed=7,
                                      backend="batched"))
    index.apply(events)
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_index(3, index)
    mgr.save_index(8, index)
    assert mgr.latest_index_step() == 8
    restored = mgr.restore_index()
    restored.check_invariants()
    assert restored.labels() == index.labels()
    assert restored.cfg == index.cfg


# ---------------------------------------------------------------------- #
# satellite regressions
# ---------------------------------------------------------------------- #
def test_labels_identical_without_scipy(monkeypatch):
    """DynamicDBSCAN.labels must work (and agree) without scipy: the
    pure-Python union-find fallback produces the identical labelling."""
    import repro.core.dynamic_dbscan as dd

    events = mixed_stream(n=250, d=3, seed=9)
    index = build_index(ClusterConfig(d=3, k=6, t=6, eps=0.5, seed=9))
    index.apply(events)
    with_scipy = index.labels()

    monkeypatch.setattr(dd, "_sp", None)  # as if scipy were uninstalled
    assert index.labels() == with_scipy


def test_emz_fixed_is_insert_only():
    index = build_index(ClusterConfig(d=3, k=4, t=4, eps=0.5,
                                      backend="emz-fixed"))
    X, _ = blobs(n=120, d=3, n_clusters=3, cluster_std=0.15, seed=0)
    ids = index.insert_batch(X[:100])
    index.insert_batch(X[100:])
    assert len(index.labels()) == 120
    with pytest.raises(NotImplementedError):
        index.delete(ids[0])


def test_emz_fixed_incremental_matches_engine_and_restores():
    """The adapter feeds EMZFixedCore incrementally (no per-query rebuild)
    and pinned out-of-order handles name stream positions, not positions
    in the frozen first batch."""
    from repro.core import EMZFixedCore

    X, _ = blobs(n=150, d=3, n_clusters=3, cluster_std=0.15, seed=1)
    cfg = ClusterConfig(d=3, k=4, t=4, eps=0.5, seed=1, backend="emz-fixed")
    index = build_index(cfg)
    ids = index.insert_batch(X[:100])
    # pinned handle below every auto id: must NOT join the frozen batch
    ids += index.apply([Insert(x, idx=i - 1000)
                        for i, x in enumerate(X[100:])])
    eng = EMZFixedCore(3, 4, 4, 0.5, seed=1)
    eng.add_batch(X[:100])
    expected = eng.add_batch(X[100:])
    assert [index.labels()[i] for i in ids] == [int(v) for v in expected]
    restored = restore_index(index.snapshot())
    assert restored.labels() == index.labels()
