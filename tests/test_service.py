"""Wire-protocol tests (PR 5): codec round-trips, ClusterService
dispatch, and the transport oracle — ``transport="process"`` must be
bit-identical to ``transport="local"`` on seeded interleaved
insert/delete streams at S ∈ {1, 2, 4}, including snapshot/restore and
rebalance; a crashed shard worker surfaces as ShardUnavailableError,
never a hang."""

import dataclasses
import socket
import threading

import numpy as np
import pytest

from repro.api import ClusterConfig, Delete, Insert, build_index, restore_index
from repro.data import blobs
from repro.service import (
    ClusterService,
    ComponentOfReq,
    DeleteBatchReq,
    DrainDeltasResp,
    ErrorResp,
    HelloReq,
    IdsReq,
    InsertBatchReq,
    InsertBatchResp,
    LabelsReq,
    LabelsResp,
    LocalTransport,
    RestoreReq,
    ShardUnavailableError,
    SnapshotReq,
    SnapshotResp,
    StatsReq,
    ValueResp,
    decode,
    encode,
    read_frame,
    serve_connection,
    write_frame,
)
from repro.service.messages import (
    decode_deltas,
    decode_handle,
    encode_deltas,
    encode_handle,
)


def cfg_for(shards, transport="local", inner="dynamic", **kw):
    base = dict(d=4, k=6, t=6, eps=0.45, seed=0, backend="sharded")
    base.update(kw)
    return ClusterConfig(shards=shards, inner_backend=inner,
                         transport=transport, **base)


# ---------------------------------------------------------------------- #
# codec
# ---------------------------------------------------------------------- #
def test_codec_roundtrips_every_payload_shape():
    msgs = [
        InsertBatchReq(X=np.arange(8.0).reshape(4, 2),
                       ids=[3, 1, 4, 1], want_digest=True),
        InsertBatchResp(ids=np.arange(4),
                        digest=np.arange(24, dtype=np.int32).reshape(4, 3, 2),
                        n_live=7),
        DeleteBatchReq(ids=np.asarray([5, 9])),
        LabelsReq(),                       # ids=None stays None
        LabelsReq(ids=[2, 7]),
        LabelsResp(ids=np.asarray([2, 7]), labels=np.asarray([-1, 0])),
        ComponentOfReq(idx=11),
        ValueResp(value=["edge", 3, 0]),   # encoded tuple handle
        ValueResp(value=None),
        DrainDeltasResp(deltas=encode_deltas([(3, None, 5), (4, 2, None)]),
                        tracked=True),
        SnapshotResp(state={"ids": np.arange(3),
                            "shard000/points": np.ones((3, 2))}),
        RestoreReq(config={"d": 4, "eps": 0.5},
                   state={"ids": np.asarray([1])}),
        ErrorResp(etype="KeyError", arg=7),
        HelloReq(),
        StatsReq(),
    ]
    for msg in msgs:
        back = decode(encode(msg))
        assert type(back) is type(msg)
        for f in dataclasses.fields(msg):
            a, b = getattr(msg, f.name), getattr(back, f.name)
            if isinstance(a, np.ndarray):
                assert a.dtype == b.dtype and np.array_equal(a, b), f.name
            elif isinstance(a, dict) and f.name in msg._array_dicts:
                assert set(a) == set(b)
                for key in a:
                    assert np.array_equal(np.asarray(a[key]), b[key]), key
            else:
                assert a == b, f.name
    # fixed dtypes are enforced at construction on both ends
    req = InsertBatchReq(X=[[1, 2]], ids=[0])
    assert req.X.dtype == np.float64 and req.ids.dtype == np.int64


def test_handle_and_delta_encodings():
    assert decode_handle(encode_handle(("edge", 1, 0))) == ("edge", 1, 0)
    assert decode_handle(encode_handle(("loop", 5))) == ("loop", 5)
    assert decode_handle(encode_handle(7)) == 7
    assert encode_handle(None) is None
    deltas = [(3, None, 5), (9, 2, None), (1, 1, 1)]
    assert decode_deltas(encode_deltas(deltas)) == deltas


def test_framing_over_a_socketpair():
    a, b = socket.socketpair()
    payloads = [b"x" * n for n in (0, 1, 1 << 17)]
    for p in payloads:
        write_frame(a, p)
    for p in payloads:
        assert read_frame(b) == p
    a.close()
    assert read_frame(b) is None  # clean EOF at a frame boundary
    b.close()


# ---------------------------------------------------------------------- #
# ClusterService over a plain backend
# ---------------------------------------------------------------------- #
def test_service_serves_any_registered_backend():
    X, _ = blobs(n=120, d=4, n_clusters=2, cluster_std=0.2, seed=1)
    index = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.45, seed=1))
    svc = ClusterService(index)
    hello = svc.handle(HelloReq())
    assert hello.backend == "dynamic" and hello.native_component_queries
    resp = svc.handle(InsertBatchReq(X=X, ids=list(range(120)),
                                     want_digest=True))
    assert [int(i) for i in resp.ids] == list(range(120))
    # digest matches the engine's own key family bit for bit
    assert resp.digest.shape == (120, 6, 4) and resp.digest.dtype == np.int64
    lab = svc.handle(LabelsReq())
    assert dict(zip(lab.ids.tolist(), lab.labels.tolist())) == index.labels()
    comp = svc.handle(ComponentOfReq(idx=0))
    assert decode_handle(comp.value) == index.component_of(0)
    # snapshot through the protocol restores into a fresh service
    snap = svc.handle(SnapshotReq())
    index2 = build_index(index.cfg)
    ClusterService(index2).handle(
        RestoreReq(config=index.cfg.to_dict(), state=dict(snap.state)))
    assert index2.labels() == index.labels()
    with pytest.raises(KeyError):
        svc.handle(DeleteBatchReq(ids=[10**6]))


def test_serve_connection_maps_exceptions_to_error_frames():
    index = build_index(ClusterConfig(d=2, k=2, t=2, eps=0.5))
    a, b = socket.socketpair()
    t = threading.Thread(target=serve_connection,
                         args=(ClusterService(index), b), daemon=True)
    t.start()
    write_frame(a, encode(DeleteBatchReq(ids=[42])))
    resp = decode(read_frame(a))
    assert isinstance(resp, ErrorResp)
    assert resp.etype == "KeyError" and resp.arg == 42
    # the connection survives the bad request
    write_frame(a, encode(InsertBatchReq(X=[[0.0, 0.0]], ids=[0])))
    assert isinstance(decode(read_frame(a)), InsertBatchResp)
    # ...and survives an undecodable frame (e.g. a version-skewed peer
    # sending an unknown message kind): ErrorResp, not a dead worker
    write_frame(a, b"this is not an npz archive")
    resp = decode(read_frame(a))
    assert isinstance(resp, ErrorResp)
    write_frame(a, encode(LabelsReq()))
    assert isinstance(decode(read_frame(a)), LabelsResp)
    a.close()
    t.join(timeout=5)
    assert not t.is_alive()


# ---------------------------------------------------------------------- #
# the transport oracle (S4): process == local, bit for bit
# ---------------------------------------------------------------------- #
def interleaved_chunks(n, d, seed):
    """Seeded mixed stream as a list of event chunks."""
    X, _ = blobs(n=n, d=d, n_clusters=4, cluster_std=0.2, seed=seed)
    rng = np.random.default_rng(seed)
    chunks, alive, row, nxt = [], [], 0, 0
    while row < n:
        chunk = []
        for _ in range(int(rng.integers(1, 7))):
            if row >= n:
                break
            chunk.append(Insert(X[row], idx=nxt))
            alive.append(nxt)
            row += 1
            nxt += 1
        if alive and rng.random() < 0.5:
            for _ in range(int(rng.integers(1, min(5, len(alive)) + 1))):
                chunk.append(Delete(alive.pop(int(rng.integers(len(alive))))))
        if chunk:
            chunks.append(chunk)
    return chunks, alive


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_process_transport_is_bit_identical_to_local(shards):
    chunks, alive = interleaved_chunks(n=220, d=4, seed=shards)
    loc = build_index(cfg_for(shards, "local"))
    proc = build_index(cfg_for(shards, "process"))
    try:
        rng = np.random.default_rng(shards)
        live = []
        for chunk in chunks:
            assert loc.apply(chunk) == proc.apply(chunk)
            for ev in chunk:
                live.append(ev.idx) if isinstance(ev, Insert) \
                    else live.remove(ev.idx)
            if live and rng.random() < 0.3:
                # labels() exact AND the opaque label() handles exact —
                # both transports run the same engines on the same stream
                assert proc.labels() == loc.labels()
                probe = [live[int(j)] for j in
                         rng.integers(0, len(live), size=6)]
                for i in probe:
                    assert proc.label(i) == loc.label(i)
        assert proc.labels() == loc.labels()
        proc.check_invariants()
    finally:
        loc.close()
        proc.close()


def test_process_snapshot_restore_and_rebalance_match_local():
    from repro.shard import SLOTS, RebalancePlan

    chunks, _ = interleaved_chunks(n=200, d=4, seed=9)
    loc = build_index(cfg_for(2, "local", seed=9))
    proc = build_index(cfg_for(2, "process", seed=9))
    back = None
    try:
        for chunk in chunks:
            loc.apply(chunk)
            proc.apply(chunk)
        # nested snapshot round-trips through the protocol; the restored
        # index spawns fresh workers and answers identically
        back = restore_index(proc.snapshot())
        assert back.cfg.transport == "process"
        assert back.labels() == loc.labels()
        plan = RebalancePlan(0, SLOTS // 3, 1)
        loc.rebalance(plan)
        back.rebalance(plan)
        assert back.labels() == loc.labels()
        back.check_invariants()
    finally:
        for ix in (loc, proc, back):
            if ix is not None:
                ix.close()


def test_process_transport_with_mixed_key_inner():
    X, _ = blobs(n=160, d=4, n_clusters=3, cluster_std=0.2, seed=3)
    loc = build_index(cfg_for(2, "local", inner="batched", seed=3))
    proc = build_index(cfg_for(2, "process", inner="batched", seed=3))
    try:
        ids = loc.insert_batch(X)
        assert proc.insert_batch(X) == ids
        assert proc.labels() == loc.labels()
        loc.delete_batch(ids[:40])
        proc.delete_batch(ids[:40])
        assert proc.labels() == loc.labels()
        proc.check_invariants()
    finally:
        loc.close()
        proc.close()


def test_process_transport_errors_are_named():
    proc = build_index(cfg_for(2, "process"))
    try:
        with pytest.raises(KeyError):
            proc.delete(123456)
        ids = proc.insert_batch(np.zeros((3, 4)))
        with pytest.raises(KeyError):
            proc.insert(np.zeros(4), idx=ids[0])
    finally:
        proc.close()


def test_transport_stats_report_wire_overhead():
    X, _ = blobs(n=100, d=4, n_clusters=2, cluster_std=0.2, seed=5)
    loc = build_index(cfg_for(2, "local", seed=5))
    proc = build_index(cfg_for(2, "process", seed=5))
    try:
        loc.insert_batch(X)
        proc.insert_batch(X)
        st_l, st_p = loc.stats(), proc.stats()
        assert st_l["process_transport"] == 0
        assert st_l["transport_bytes_sent"] == 0  # zero-copy in-process
        assert st_p["process_transport"] == 1
        assert st_p["transport_bytes_sent"] > 0
        assert st_p["transport_bytes_received"] > 0
        assert st_p["transport_round_trips"] >= 2
        # per-shard engine counters still aggregate across the wire
        assert "n_links" in st_p and st_p["n_links"] == st_l["n_links"]
    finally:
        loc.close()
        proc.close()


# ---------------------------------------------------------------------- #
# crash behavior (S4): named error, no hang
# ---------------------------------------------------------------------- #
def test_shard_crash_surfaces_as_shard_unavailable():
    X, _ = blobs(n=80, d=4, n_clusters=2, cluster_std=0.2, seed=6)
    proc = build_index(cfg_for(2, "process", seed=6))
    try:
        proc.insert_batch(X)
        victim = proc.clients[1]
        victim._proc.kill()
        victim._proc.wait()
        with pytest.raises(ShardUnavailableError, match="shard 1"):
            for _ in range(3):  # first op to touch shard 1 must raise
                victim.labels()
        # a closed transport keeps failing fast instead of reconnecting
        victim.close()
        with pytest.raises(ShardUnavailableError):
            victim.ids()
    finally:
        proc.close()  # idempotent, including the dead shard


def test_spawn_failure_cleans_up_spawned_siblings():
    # unsupported inner backends are rejected before any worker spawns
    with pytest.raises(ValueError, match="cannot be sharded"):
        build_index(cfg_for(2, "process", inner="naive"))


# ---------------------------------------------------------------------- #
# transports behind one ABC
# ---------------------------------------------------------------------- #
def test_local_transport_is_the_protocol_zero_copy():
    lt = LocalTransport(ClusterConfig(d=2, k=2, t=2, eps=0.5))
    ids, digest = lt.insert_batch(np.zeros((2, 2)), ids=[0, 1],
                                  want_digest=True)
    assert ids == [0, 1] and digest.shape == (2, 2, 2)
    assert lt.bytes_sent == 0 and lt.bytes_received == 0
    # the generic request() path works too (message-level compatibility)
    assert isinstance(lt.request(IdsReq()).ids, np.ndarray)
    assert lt.hello().native_component_queries
    lt.close()


def test_config_validates_transport_by_name():
    with pytest.raises(ValueError, match="transport"):
        ClusterConfig(d=2, k=2, t=2, eps=0.5, transport="carrier-pigeon")
    for tr in ("local", "process"):
        ClusterConfig(d=2, k=2, t=2, eps=0.5, transport=tr)


# ---------------------------------------------------------------------- #
# observability must not perturb the clustering (PR 7)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("transport", ["local", "process"])
def test_obs_toggle_is_label_invariant(transport):
    """The instrumented run answers bit-identically to the bare one on
    the same seeded stream — tracing rides out-of-band and the no-op
    registry keeps the disabled path untouched."""
    chunks, alive = interleaved_chunks(n=150, d=4, seed=11)
    bare = build_index(cfg_for(2, transport, seed=11, obs=False))
    traced = build_index(cfg_for(2, transport, seed=11, obs=True))
    try:
        for chunk in chunks:
            assert bare.apply(chunk) == traced.apply(chunk)
        assert traced.labels() == bare.labels()
        for i in alive[:12]:
            assert traced.label(i) == bare.label(i)
        traced.check_invariants()
        # the instrumented run actually observed something...
        snaps = traced.obs_snapshot()
        assert snaps and any(s["metrics"] for s in snaps)
        # ...while the bare run carries the shared null handle
        assert not bare.obs.enabled and bare.obs_snapshot() == []
    finally:
        bare.close()
        traced.close()


def test_untraced_requests_put_no_obs_bytes_on_the_wire():
    """Frame-level guard: with obs disabled the encoded request stream is
    byte-identical to the pre-obs wire format — no reserved keys leak."""
    req = InsertBatchReq(X=np.arange(8.0).reshape(4, 2), ids=[0, 1, 2, 3])
    raw = encode(req)
    assert b"__trace__" not in raw and b"__spans__" not in raw
    again = InsertBatchReq(X=np.arange(8.0).reshape(4, 2), ids=[0, 1, 2, 3])
    assert encode(again) == raw  # deterministic and sidecar-free
    # a traced peer's sidecar survives the round trip without touching
    # the dataclass fields
    traced_req = InsertBatchReq(X=np.arange(8.0).reshape(4, 2),
                                ids=[0, 1, 2, 3])
    traced_req.trace_ctx = {"t": 9, "s": 4}
    back = decode(encode(traced_req))
    assert back.trace_ctx == {"t": 9, "s": 4}
    assert np.array_equal(back.X, req.X)
