"""Query-hot-path tests for the sharded backend (PR 3).

Property test: a seeded random interleaving of inserts / deletes /
``label()`` / ``labels()`` at S ∈ {1, 2, 4} must answer every query
exactly like a fresh index rebuilt from the full event history — with the
incremental merge on and off, with the thread-pool fan-out on and off,
and across ``rebalance()`` and snapshot/restore.  Plus the protocol
additions (``component_of`` / ``core_anchor_of`` / ``drain_deltas``), the
bridge's pre-validated mutation errors, and the single-hash-pass routing
of mixed-key inners.
"""

import numpy as np
import pytest

from repro.api import (
    NOISE,
    ClusterConfig,
    Delete,
    Insert,
    build_index,
    restore_index,
)
from repro.data import blobs
from repro.shard import BoundaryBridge, ShardedIndex

from test_api import assert_same_partition


def hot_cfg(shards, inner="dynamic", **kw):
    base = dict(d=4, k=6, t=6, eps=0.45, seed=0, backend="sharded")
    base.update(kw)
    return ClusterConfig(shards=shards, inner_backend=inner, **base)


def groups_of(lab):
    """Partition of the labelling as a frozenset of frozensets (noise
    kept separate so opaque label() ids compare against canonical ones)."""
    noise = frozenset(i for i, v in lab.items() if v == NOISE)
    by = {}
    for i, v in lab.items():
        if v != NOISE:
            by.setdefault(v, set()).add(i)
    return noise, frozenset(frozenset(g) for g in by.values())


# ---------------------------------------------------------------------- #
# the oracle property test (S3)
# ---------------------------------------------------------------------- #
def drive_interleaved(cfg, seed, n=360, with_restore=True, with_rebalance=True):
    """Random insert/delete/query interleaving; every query is checked
    against a fresh rebuild of the same event history."""
    X, _ = blobs(n=n, d=cfg.d, n_clusters=4, cluster_std=0.2, seed=seed)
    rng = np.random.default_rng(seed)
    index = build_index(cfg)
    oracle_cfg = cfg.replace(incremental_merge=False, workers=0)
    events, alive, row, nxt = [], [], 0, 0
    half_done = False
    while row < n or alive:
        # one update chunk: a run of inserts and/or a run of deletes
        chunk = []
        n_ins = int(rng.integers(0, 7)) if row < n else 0
        for _ in range(min(n_ins, n - row)):
            chunk.append(Insert(X[row], idx=nxt))
            alive.append(nxt)
            row += 1
            nxt += 1
        if alive and rng.random() < 0.6:
            for _ in range(int(rng.integers(1, min(6, len(alive)) + 1))):
                victim = alive.pop(int(rng.integers(len(alive))))
                chunk.append(Delete(victim))
        if not chunk:
            break
        events.extend(chunk)
        index.apply(chunk)

        # hot-path point queries against the full labelling
        if alive:
            lab = index.labels()
            noise, parts = groups_of(lab)
            probe = [alive[int(j)] for j in rng.integers(0, len(alive),
                                                         size=min(8, len(alive)))]
            point = {i: index.label(i) for i in probe}
            p_noise, p_parts = groups_of(point)
            assert p_noise == noise & set(probe)
            for g in p_parts:  # co-labelled probes are co-clustered
                assert any(g <= big for big in parts), (g, parts)

        if with_rebalance and not half_done and row >= n // 2:
            half_done = True
            # snapshot/restore + rebalance mid-stream: partition invariant
            before = index.labels()
            index = restore_index(index.snapshot())
            from repro.shard import SLOTS, RebalancePlan
            index.rebalance(RebalancePlan(0, SLOTS // 3, cfg.shards - 1))
            assert index.labels() == before
            index.check_invariants()

        # periodic exact-oracle check: fresh rebuild of the history
        if rng.random() < 0.15:
            oracle = build_index(oracle_cfg)
            oracle.apply(events)
            assert oracle.labels() == index.labels()

    oracle = build_index(oracle_cfg)
    oracle.apply(events)
    assert oracle.labels() == index.labels()
    index.check_invariants()
    return index, events


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("seed", [0, 1])
def test_interleaved_stream_matches_fresh_rebuild_oracle(seed, shards):
    cfg = hot_cfg(shards, seed=seed)
    index, events = drive_interleaved(cfg, seed)
    # and the single-shard inner reference agrees on the partition
    ref = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.45, seed=seed))
    ref.apply(events)
    assert_same_partition(ref.labels(), index.labels())


@pytest.mark.parametrize("incremental", [True, False])
def test_workers_fanout_is_equivalent_to_serial(incremental):
    cfg = hot_cfg(4, inner="batched", seed=3, incremental_merge=incremental)
    serial, events = drive_interleaved(cfg, 3, with_rebalance=False)
    threaded = build_index(cfg.replace(workers=2))
    threaded.apply(events)
    assert threaded._pool is not None
    assert threaded.labels() == serial.labels()
    threaded.check_invariants()


def test_incremental_off_for_recompute_inner():
    """emz-static has no native component queries: the index must fall
    back to the rebuild merge even with incremental_merge=True."""
    X, _ = blobs(n=150, d=4, n_clusters=3, cluster_std=0.15, seed=5)
    sh = build_index(hot_cfg(2, inner="emz-static", seed=5))
    assert sh._incremental is False
    assert sh.native_component_queries is False
    sh.insert_batch(X)
    assert sh.stats()["n_merge_passes"] == 0
    sh.labels()
    assert sh.stats()["n_merge_passes"] == 1
    with pytest.raises(NotImplementedError, match="core-anchor"):
        sh.core_anchor_of(int(sh.ids()[0]))


def test_incremental_label_avoids_merge_passes():
    """The acceptance property in miniature: interleaved label() after
    mutations never triggers a merge pass on the incremental path."""
    X, _ = blobs(n=300, d=4, n_clusters=3, cluster_std=0.15, seed=7)
    sh = build_index(hot_cfg(3, seed=7))
    ids = sh.insert_batch(X[:250])
    rng = np.random.default_rng(7)
    for j in range(40):
        sh.insert(X[250 + j % 50])
        sh.delete(ids[j])
        for _ in range(4):
            sh.label(int(ids[int(rng.integers(40, len(ids)))]))
    st = sh.stats()
    assert st["n_merge_passes"] == 0
    assert st["n_boundary_merges"] == 0  # no full labelling either
    assert st["n_quotient_builds"] > 0   # label() built boundary quotients
    assert st["bridge_epoch"] > 0
    # the quotient is epoch-stamped: repeated queries between mutations
    # reuse it instead of rebuilding
    builds = st["n_quotient_builds"]
    for _ in range(5):
        sh.label(int(ids[50]))
    assert sh.stats()["n_quotient_builds"] == builds


# ---------------------------------------------------------------------- #
# protocol additions: component_of / core_anchor_of / drain_deltas
# ---------------------------------------------------------------------- #
def test_component_of_and_core_anchor_contracts():
    X, _ = blobs(n=200, d=4, n_clusters=3, cluster_std=0.15, seed=2)
    for cfg in (ClusterConfig(d=4, k=6, t=6, eps=0.45, seed=2),
                hot_cfg(3, seed=2)):
        index = build_index(cfg)
        assert index.native_component_queries
        ids = index.insert_batch(X)
        lab = index.labels()
        for i in ids[::17]:
            comp = index.component_of(i)
            anchor = index.core_anchor_of(i)
            if lab[i] == NOISE:
                assert anchor is None
            else:
                assert anchor is not None
                # the anchor is a core in the same cluster
                assert index.is_core(anchor)
                assert lab[anchor] == lab[i]
                # component handles agree exactly with label()
                assert comp == index.label(i)
        with pytest.raises(KeyError):
            index.component_of(10**9)


def test_drain_deltas_feed():
    X, _ = blobs(n=120, d=4, n_clusters=2, cluster_std=0.15, seed=4)
    index = build_index(hot_cfg(2, seed=4))
    assert index.drain_deltas() == []  # first call activates tracking
    ids = index.insert_batch(X)
    deltas = index.drain_deltas()
    touched = {i for i, _, _ in deltas}
    assert touched  # insertions produced attachment changes
    assert touched <= set(ids)
    for i, old, new in deltas:
        assert old is None  # fresh points have no prior attachment
    assert index.drain_deltas() == []  # drained
    index.delete_batch(ids[:10])
    gone = {i for i, _, new in index.drain_deltas() if new is None}
    assert set(ids[:10]) <= gone
    # recompute backends advertise "no tracking"
    emz = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.45, seed=4,
                                    backend="emz-static"))
    assert emz.drain_deltas() is None
    sh = build_index(hot_cfg(2, inner="emz-static", seed=4))
    assert sh.drain_deltas() is None


def test_drain_deltas_reports_reanchored_then_deleted_point():
    """Regression: a border point whose anchor dies, re-anchors, and is
    then deleted within ONE drain period must still surface in the feed
    as (idx, original-anchor, None) — the detach/re-attach records have
    to compose under compaction instead of cancelling to a no-op."""
    X, _ = blobs(n=300, d=4, n_clusters=3, cluster_std=0.25, seed=2)
    index = build_index(ClusterConfig(d=4, k=8, t=8, eps=0.45, seed=2))
    ids = index.insert_batch(X)
    eng = index.engine
    case = next((y, a) for y, a in sorted(eng.attach.items())
                if a is not None and not eng.is_core(y))
    y, a = case
    index.drain_deltas()
    index.delete(a)
    if y in index and not eng.is_core(y) and eng.attach.get(y) is not None:
        index.delete(y)
        entries = [e for e in index.drain_deltas() if e[0] == y]
        assert entries == [(y, a, None)]
    else:  # layout shifted: still exercise delete-after-detach
        if y in index:
            index.delete(y)
        assert all(new is None for i, _, new in index.drain_deltas()
                   if i == y)
    assert ids  # stream stayed live


# ---------------------------------------------------------------------- #
# bridge mutation errors are pre-validated and named (S2)
# ---------------------------------------------------------------------- #
def test_bridge_rejects_unknown_ids_before_mutating():
    bridge = BoundaryBridge(t=2, k=2)
    bridge.insert(0, [b"a", b"b"], shard=0)
    bridge.insert(1, [b"a", b"c"], shard=1)
    before = (dict(bridge.support), {b: set(m) for b, m in bridge.members.items()},
              bridge.n_boundary_buckets)
    with pytest.raises(KeyError, match="cannot delete index 7"):
        bridge.delete(7, shard=0)
    with pytest.raises(KeyError, match="cannot move index 7"):
        bridge.move(7, 0, 1)
    with pytest.raises(KeyError, match="index 1 already present"):
        bridge.insert(1, [b"z", b"z"], shard=0)
    after = (dict(bridge.support), {b: set(m) for b, m in bridge.members.items()},
             bridge.n_boundary_buckets)
    assert before == after  # nothing mutated
    bridge.check({0: 0, 1: 1})


# ---------------------------------------------------------------------- #
# mixed-key inners route from the one device-hash pass (S1)
# ---------------------------------------------------------------------- #
def test_mixed_key_routing_shares_the_device_hash_pass(monkeypatch):
    sh = build_index(hot_cfg(2, inner="batched", seed=6))
    calls = {"codes": 0, "device": 0}
    orig_codes = sh.lsh.codes_batch
    orig_device = sh.lsh.device_keys_batch
    monkeypatch.setattr(sh.lsh, "codes_batch",
                        lambda X: calls.__setitem__("codes", calls["codes"] + 1)
                        or orig_codes(X))
    monkeypatch.setattr(sh.lsh, "device_keys_batch",
                        lambda X: calls.__setitem__("device", calls["device"] + 1)
                        or orig_device(X))
    X, _ = blobs(n=64, d=4, n_clusters=2, cluster_std=0.2, seed=6)
    sh.insert_batch(X)
    assert calls == {"codes": 0, "device": 1}  # exactly one hash pass
    # exact-key inners still share the single codes pass
    she = build_index(hot_cfg(2, inner="dynamic", seed=6))
    calls2 = {"codes": 0}
    orig2 = she.lsh.codes_batch
    monkeypatch.setattr(she.lsh, "codes_batch",
                        lambda X: calls2.__setitem__("codes", calls2["codes"] + 1)
                        or orig2(X))
    she.insert_batch(X)
    assert calls2 == {"codes": 1}
    # routing is deterministic and placement-consistent under rebalance
    assert isinstance(sh, ShardedIndex)
    sh.check_invariants()
