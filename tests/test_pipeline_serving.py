"""Data pipeline (+DBSCAN curation) and serving engine behaviour tests."""

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.data.pipeline import CurationFilter, Pipeline, SyntheticTokenStream
from repro.models.registry import build_model
from repro.serving.engine import Request, ServingEngine


def test_synthetic_stream_shapes():
    src = SyntheticTokenStream(vocab_size=100, seq_len=16, batch=8)
    batch = next(iter(src))
    assert batch["tokens"].shape == (8, 16)
    assert batch["labels"].shape == (8, 16)
    assert batch["embeddings"].shape == (8, 16)
    assert (batch["tokens"] < 100).all()


def test_curation_balance_policy_downsamples_dominant_cluster():
    rng = np.random.default_rng(0)
    cf = CurationFilter(d=4, k=6, t=6, eps=0.5, policy="balance",
                        max_per_cluster_frac=0.3, window=10_000)
    # one dominant tight cluster + scattered noise
    dom = rng.normal(size=(300, 4)) * 0.05
    scat = rng.uniform(-6, 6, size=(60, 4))
    keep_dom = cf.filter(dom)
    keep_scat = cf.filter(scat)
    assert keep_dom.mean() < 0.9          # dominant cluster throttled
    assert keep_scat.mean() > 0.8          # noise/low-density kept


def test_curation_sliding_window_deletes():
    cf = CurationFilter(d=3, k=4, t=4, eps=0.5, window=50)
    rng = np.random.default_rng(1)
    for _ in range(6):
        cf.filter(rng.normal(size=(20, 3)))
    assert len(cf.index) <= 50
    cf.index.check_invariants()


def test_pipeline_prefetch_and_fixed_shape():
    src = SyntheticTokenStream(vocab_size=64, seq_len=8, batch=6, seed=2)
    cf = CurationFilter(d=16, k=4, t=4, eps=0.6, policy="balance")
    pipe = Pipeline(iter(src), curation=cf, prefetch=2)
    for _ in range(4):
        b = next(pipe)
        assert b["tokens"].shape == (6, 8)
    pipe.close()
    assert cf.n_seen >= 24


@pytest.mark.parametrize("arch", ["granite-20b", "mamba2-780m"])
def test_serving_engine_drains_requests(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch=4, kv_len=32)
    rng = np.random.default_rng(0)
    for rid in range(6):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=rng.integers(2, 5)),
            max_new_tokens=4,
        ))
    done = eng.run_until_drained(max_steps=200)
    assert sorted(done) == list(range(6))
    for r in done.values():
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out_tokens)


def test_serving_engine_isolation_between_slots():
    """A request's output must not depend on which other requests share the
    batch (active-mask correctness)."""
    cfg = get_config("granite-20b").smoke()
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    prompt = np.array([5, 9, 3], dtype=np.int64)

    def run(extra):
        eng = ServingEngine(model, params, batch=4, kv_len=32)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
        for rid, p in enumerate(extra, start=1):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
        return eng.run_until_drained(max_steps=200)[0].out_tokens

    alone = run([])
    crowded = run([np.array([7, 7]), np.array([1, 2, 3, 4])])
    assert alone == crowded


@pytest.mark.parametrize("cluster_shards", [1, 2])
def test_request_clustering_groups_similar(cluster_shards):
    cfg = get_config("mamba2-780m").smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    eng = ServingEngine(model, params, batch=2, kv_len=16,
                        cluster_requests=True, embed_dim=4,
                        cluster_shards=cluster_shards)
    if cluster_shards > 1:
        assert eng.clusterer.cfg.backend == "sharded"
    rng = np.random.default_rng(3)
    center = rng.normal(size=4)
    for rid in range(8):
        emb = center + 0.01 * rng.normal(size=4) if rid % 2 == 0 else \
            rng.uniform(-5, 5, size=4)
        eng.submit(Request(rid=rid, prompt=np.array([1, 2]),
                           max_new_tokens=2, embedding=emb))
    done = eng.run_until_drained(max_steps=400)
    assert len(done) == 8


def test_request_dataclass_declares_engine_state_fields():
    """_cidx/_next are declared optional fields (not ad-hoc dynamic
    attributes), so dataclass introspection sees the full request."""
    import dataclasses

    names = {f.name for f in dataclasses.fields(Request)}
    assert {"_cidx", "_next"} <= names
    r = Request(rid=0, prompt=np.array([1]))
    assert r._cidx is None and r._next is None
    assert dataclasses.asdict(r)["_cidx"] is None


def test_request_window_is_a_deque():
    """The admission window evicts at the head on every submit past
    capacity — O(1) with a deque (the hot loop at high request rates)."""
    from collections import deque

    cfg = get_config("mamba2-780m").smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(4))
    eng = ServingEngine(model, params, batch=1, kv_len=16,
                        cluster_requests=True, embed_dim=4)
    assert isinstance(eng._req_window, deque)
    rng = np.random.default_rng(5)
    for rid in range(4 * eng.B + 3):  # overflow the window
        eng.submit(Request(rid=rid, prompt=np.array([1, 2]),
                           max_new_tokens=1, embedding=rng.normal(size=4)))
    assert len(eng._req_window) == 4 * eng.B
    assert len(eng.clusterer) == 4 * eng.B
    eng.run_until_drained(max_steps=600)
    eng.close()


def test_serving_engine_obs_telemetry():
    """An instrumented engine records per-op latency and scheduler-state
    gauges; the default no-op handle records nothing."""
    from repro.obs import Obs

    cfg = get_config("granite-20b").smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    obs = Obs(proc="serving")
    eng = ServingEngine(model, params, batch=2, kv_len=16, obs=obs)
    rng = np.random.default_rng(3)
    for rid in range(3):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=3),
            max_new_tokens=2,
        ))
    eng.run_until_drained(max_steps=100)
    m = obs.snapshot()["metrics"]
    assert m["serving.submit_us"]["count"] == 3
    assert m["serving.step_us"]["count"] >= 1
    assert m["serving.queue_depth"]["value"] == 0   # drained
    # the gauge reflects slots active during the last decode step — the
    # final request was still in flight when it ran
    assert m["serving.active_slots"]["value"] <= 1
    spans = {s["name"] for s in obs.tracer.export()}
    assert "serving.submit" in spans
    # the bare engine shares the null handle: nothing observed
    bare = ServingEngine(model, params, batch=2, kv_len=16)
    assert not bare.obs.enabled
