"""Per-architecture smoke tests: reduced same-family configs run one
forward/train step and one decode step on CPU; output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.registry import build_model
from repro.optim import AdamW, warmup_cosine
from repro.training import make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    if cfg.family == "audio":
        s_txt = S // 4
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_txt))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_txt))),
        }
    if cfg.family == "vlm":
        s_txt = S - cfg.n_patches
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_txt))),
            "patches": jnp.asarray(
                rng.normal(size=(B, cfg.n_patches, cfg.d_vision)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_txt))),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    opt = AdamW(lr=warmup_cosine(1e-3, 10, 100))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, grad_accum=1))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch, loss)
    # params actually changed
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(new_params), jax.tree.leaves(params))
    )
    assert delta > 0
    # second step decreases nothing catastrophic / remains finite
    _, _, m2 = step(new_params, new_opt, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params, _ = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    n_txt = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        assert logits.shape[:2] == (B, n_txt + cfg.n_patches)
    else:
        assert logits.shape[:2] == (B, n_txt)
    assert logits.shape[-1] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    params, _ = model.init(jax.random.PRNGKey(2))
    kv_len = 64
    caches, _ = model.decode_init(B, kv_len)
    token = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)))
    step = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos)
    )
    logits, caches = step(params, caches, token, jnp.asarray(0, jnp.int32))
    logits2, caches = step(params, caches, token, jnp.asarray(1, jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["granite-20b", "mamba2-780m", "hymba-1.5b"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced prefill logits."""
    from dataclasses import replace

    cfg = replace(get_config(arch).smoke(), dtype="float32")  # exactness test
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    params, _ = model.init(jax.random.PRNGKey(3))
    toks = rng.integers(0, cfg.vocab_size, (B, 8))
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    full = model.forward(params, batch)  # (B, 8, VP)
    caches, _ = model.decode_init(B, 16)
    outs = []
    for t in range(8):
        logits, caches = model.decode_step(
            params, caches, jnp.asarray(toks[:, t : t + 1]),
            jnp.asarray(t, jnp.int32),
        )
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        atol=2e-4, rtol=2e-4,
    )
