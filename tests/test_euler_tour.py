"""Randomized oracle tests for the skip-list Euler Tour forest."""

import random

import pytest

from repro.core.euler_tour import EulerTourForest
from repro.core.skiplist import SkipListSeq


class ForestOracle:
    """Naive adjacency-set forest with BFS connectivity."""

    def __init__(self):
        self.adj = {}

    def add_node(self, v):
        self.adj[v] = set()

    def remove_node(self, v):
        assert not self.adj[v]
        del self.adj[v]

    def connected(self, u, v):
        if u == v:
            return True
        seen = {u}
        stack = [u]
        while stack:
            x = stack.pop()
            for y in self.adj[x]:
                if y == v:
                    return True
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return False

    def link(self, u, v):
        if self.connected(u, v):
            return False
        self.adj[u].add(v)
        self.adj[v].add(u)
        return True

    def cut(self, u, v):
        if v not in self.adj[u]:
            return False
        self.adj[u].remove(v)
        self.adj[v].remove(u)
        return True

    def component(self, v):
        seen = {v}
        stack = [v]
        while stack:
            x = stack.pop()
            for y in self.adj[x]:
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return frozenset(seen)


def check_consistent(f: EulerTourForest, o: ForestOracle, nodes):
    # roots must induce exactly the oracle's components
    by_root = {}
    for v in nodes:
        by_root.setdefault(f.root(v), set()).add(v)
    comps = {o.component(v) for v in nodes}
    assert {frozenset(s) for s in by_root.values()} == comps
    # spot-check pairwise connectivity
    vs = list(nodes)
    rng = random.Random(len(nodes))
    for _ in range(min(30, len(vs) * 2)):
        a, b = rng.choice(vs), rng.choice(vs)
        assert f.connected(a, b) == o.connected(a, b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_link_cut(seed):
    rng = random.Random(seed)
    f = EulerTourForest(seed=seed)
    o = ForestOracle()
    n = 40
    for v in range(n):
        f.add_node(v)
        o.add_node(v)
    edges = set()
    for step in range(600):
        op = rng.random()
        u, v = rng.randrange(n), rng.randrange(n)
        if u == v:
            continue
        if op < 0.55:
            r1, r2 = f.link(u, v), o.link(u, v)
            assert r1 == r2
            if r1:
                edges.add(frozenset((u, v)))
        else:
            if edges and rng.random() < 0.8:
                u, v = tuple(rng.choice(sorted(tuple(sorted(e)) for e in edges)))
            r1, r2 = f.cut(u, v), o.cut(u, v)
            assert r1 == r2
            edges.discard(frozenset((u, v)))
        if step % 50 == 0:
            check_consistent(f, o, range(n))
    check_consistent(f, o, range(n))


def test_tour_structure_valid():
    """The stored sequence of each tree must be a valid Euler circuit."""
    rng = random.Random(7)
    f = EulerTourForest(seed=7)
    n = 25
    for v in range(n):
        f.add_node(v)
    for _ in range(200):
        u, v = rng.randrange(n), rng.randrange(n)
        if u == v:
            continue
        if rng.random() < 0.6:
            f.link(u, v)
        else:
            f.cut(u, v)
    seen_roots = set()
    for v in range(n):
        r = f.root(v)
        if r in seen_roots:
            continue
        seen_roots.add(r)
        els = [e.payload for e in SkipListSeq.iter_seq(f._loop[v])]
        # walk the circuit: consecutive elements must chain positions
        def pos_of(p):
            return (p[1], p[1]) if p[0] == "loop" else (p[1], p[2])
        for a, b in zip(els, els[1:] + els[:1]):
            pa, pb = pos_of(a), pos_of(b)
            assert pa[1] == pb[0], (els, a, b)
        # each loop appears once, each edge twice (once per direction)
        loops = [p for p in els if p[0] == "loop"]
        assert len(loops) == len(set(loops))
        dir_edges = [p for p in els if p[0] == "edge"]
        assert len(dir_edges) == len(set(dir_edges))
        assert {(p[2], p[1]) for p in dir_edges} == {(p[1], p[2]) for p in dir_edges}


def test_remove_node():
    f = EulerTourForest()
    for v in "abc":
        f.add_node(v)
    f.link("a", "b")
    with pytest.raises(ValueError):
        f.remove_node("a")
    f.cut("a", "b")
    f.remove_node("a")
    assert "a" not in f
    assert f.connected("b", "b")


@pytest.mark.parametrize("backend", ["skiplist", "treap"])
@pytest.mark.parametrize("seed", [0, 5])
def test_backends_random_link_cut(backend, seed):
    """Both sequence backends must satisfy the forest oracle."""
    rng = random.Random(seed)
    f = EulerTourForest(seed=seed, backend=backend)
    o = ForestOracle()
    n = 30
    for v in range(n):
        f.add_node(v)
        o.add_node(v)
    for step in range(400):
        u, v = rng.randrange(n), rng.randrange(n)
        if u == v:
            continue
        if rng.random() < 0.55:
            assert f.link(u, v) == o.link(u, v)
        else:
            assert f.cut(u, v) == o.cut(u, v)
        if step % 80 == 0:
            check_consistent(f, o, range(n))
    check_consistent(f, o, range(n))
