"""Tests for the vectorised structure-of-arrays engine (backend="soa"):
kernel bit-exactness for the bucket/core ops, seeded oracle equivalence
against the sequential dict engines on mixed insert/delete/label streams
(including snapshot/restore round-trips), and inner_backend="soa" under
ShardedIndex at S in {1, 2, 4}."""

import numpy as np
import pytest

from repro.api import (
    ClusterConfig,
    build_index,
    restore_index,
)
from repro.data import blobs

from test_api import assert_same_partition, mixed_stream


def cfg4(**kw):
    base = dict(d=4, k=8, t=8, eps=0.45, seed=0)
    base.update(kw)
    return ClusterConfig(**base)


# ---------------------------------------------------------------------- #
# kernel bit-exactness: Pallas interpret vs jnp ref vs numpy
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("n,t,nb", [(1, 1, 1), (7, 3, 5), (203, 7, 37),
                                    (256, 8, 128), (301, 10, 513)])
def test_bucket_core_stats_matches_ref(n, t, nb):
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(n * 31 + t)
    slots = jnp.asarray(rng.integers(0, nb, (n, t)), jnp.int32)
    sizes = jnp.asarray(rng.integers(0, 12, nb), jnp.int32)
    for k in (1, 3, 9):
        sr, cr = ops.bucket_core_stats(slots, sizes, k=k, impl="ref")
        sp, cp = ops.bucket_core_stats(slots, sizes, k=k,
                                       impl="pallas_interpret")
        occ = np.asarray(sizes)[np.asarray(slots)]
        want = (occ >= k).sum(axis=1).astype(np.int32)
        assert np.array_equal(np.asarray(sr), want)
        assert np.array_equal(np.asarray(sp), want)
        assert np.array_equal(np.asarray(cr), (want > 0).astype(np.int32))
        assert np.array_equal(np.asarray(cp), (want > 0).astype(np.int32))


@pytest.mark.parametrize("n,t,nb", [(1, 1, 1), (7, 3, 5), (203, 7, 37),
                                    (256, 8, 128), (301, 10, 513)])
def test_slot_counts_matches_bincount(n, t, nb):
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(n * 17 + nb)
    slots = jnp.asarray(rng.integers(0, nb, (n, t)), jnp.int32)
    want = np.bincount(np.asarray(slots).ravel(), minlength=nb)
    for impl in ("ref", "pallas_interpret"):
        got = np.asarray(ops.slot_counts(slots, n_slots=nb, impl=impl))
        assert np.array_equal(got, want.astype(np.int32))


# ---------------------------------------------------------------------- #
# oracle equivalence: soa vs the sequential dict engines
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["soa", "soa-device"])
def test_soa_registered_and_event_stream_matches_dynamic(backend):
    cfg = cfg4()
    ref = build_index(cfg.replace(backend="dynamic"))
    soa = build_index(cfg.replace(backend=backend))
    for ev in mixed_stream(n=250, seed=3):
        assert ref.apply([ev]) == soa.apply([ev])
    assert ref.labels() == soa.labels()
    assert sorted(ref.ids()) == sorted(soa.ids())
    soa.check_invariants()


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("orphans", [True, False])
def test_soa_batches_match_batched_labels_exactly(seed, orphans):
    """Batch-grained mixed stream with pinned out-of-order ids: identical
    label dicts (not just same partition) and identical compacted journal
    deltas at every step."""
    rng = np.random.default_rng(seed + 50)
    X, _ = blobs(n=400, d=4, n_clusters=4, cluster_std=0.3, seed=seed)
    cfg = cfg4(seed=seed, attach_orphans=orphans)
    A = build_index(cfg.replace(backend="batched"))
    B = build_index(cfg.replace(backend="soa"))
    pos, alive = 0, []
    while pos < len(X):
        b = int(rng.integers(1, 50))
        chunk = X[pos:pos + b]
        pos += b
        ids = None
        if rng.random() < 0.3:
            base = 10_000 + pos * 10
            ids = [None if rng.random() < 0.5 else base + j
                   for j in range(len(chunk))]
        assert A.insert_batch(chunk, ids=ids) == \
            (got := B.insert_batch(chunk, ids=ids))
        alive.extend(got)
        assert sorted(A.drain_deltas()) == sorted(B.drain_deltas())
        if rng.random() < 0.5 and len(alive) > 30:
            nd = int(rng.integers(1, min(20, len(alive) - 10)))
            dels = [alive.pop(int(rng.integers(len(alive))))
                    for _ in range(nd)]
            A.delete_batch(dels)
            B.delete_batch(dels)
            assert sorted(A.drain_deltas()) == sorted(B.drain_deltas())
        assert A.labels() == B.labels()
    A.check_invariants()
    B.check_invariants()


def test_soa_point_queries_agree_with_bulk_labels():
    cfg = cfg4(seed=1)
    ix = build_index(cfg.replace(backend="soa"))
    X, _ = blobs(n=300, d=4, n_clusters=3, cluster_std=0.3, seed=1)
    ids = ix.insert_batch(X)
    labs = ix.labels()
    for i in ids[::7]:
        assert ix.label(i) == ix.component_of(i) == labs[i]
        if ix.is_core(i):
            assert ix.core_anchor_of(i) == i


def test_soa_snapshot_restore_roundtrip_mid_stream():
    cfg = cfg4(seed=2)
    ix = build_index(cfg.replace(backend="soa"))
    X, _ = blobs(n=350, d=4, n_clusters=4, cluster_std=0.3, seed=2)
    ix.insert_batch(X[:200])
    ix.delete_batch(list(ix.ids())[::5])
    rest = restore_index(ix.snapshot())
    assert rest.labels() == ix.labels()
    assert rest.ids() == ix.ids()
    rest.check_invariants()
    # the restored index keeps tracking the original under further updates
    a = ix.insert_batch(X[200:])
    b = rest.insert_batch(X[200:])
    assert a == b
    assert rest.labels() == ix.labels()


def test_soa_rejects_duplicate_ids_atomically():
    ix = build_index(cfg4().replace(backend="soa"))
    X, _ = blobs(n=10, d=4, n_clusters=1, cluster_std=0.2, seed=0)
    ix.insert_batch(X[:3], ids=[7, 8, 9])
    with pytest.raises(KeyError):
        ix.insert_batch(X[3:6], ids=[11, 8, 12])
    # the failed batch must not have committed any of its rows
    assert sorted(ix.ids()) == [7, 8, 9]


# ---------------------------------------------------------------------- #
# sharded composition: inner_backend="soa"
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_soa_matches_inner_dynamic(shards):
    base = dict(d=4, k=8, t=8, eps=0.45, seed=0)
    ref = build_index(ClusterConfig(backend="dynamic", **base))
    sh = build_index(ClusterConfig(backend="sharded", shards=shards,
                                   inner_backend="soa", **base))
    for ev in mixed_stream(n=220, seed=5):
        assert ref.apply([ev]) == sh.apply([ev])
    assert_same_partition(ref.labels(), sh.labels())
    sh.check_invariants()


def test_sharded_soa_snapshot_roundtrip():
    cfg = ClusterConfig(backend="sharded", shards=2, inner_backend="soa",
                        d=4, k=8, t=8, eps=0.45, seed=0)
    sh = build_index(cfg)
    X, _ = blobs(n=240, d=4, n_clusters=3, cluster_std=0.3, seed=4)
    sh.insert_batch(X)
    sh.delete_batch(list(sh.ids())[::4])
    rest = restore_index(sh.snapshot())
    assert rest.labels() == sh.labels()
    assert rest.ids() == sh.ids()
