"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.flash_attention as fa
import repro.kernels.lsh_hash as lh
import repro.kernels.pairwise_dist as pd
from repro.kernels import ref


# --------------------------------------------------------------------- #
# lsh_hash
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n,d,t", [(64, 4, 3), (200, 16, 10), (33, 7, 5), (256, 20, 8)])
def test_lsh_hash_matches_ref(n, d, t):
    rng = np.random.default_rng(n + d + t)
    x = rng.normal(size=(n, d)).astype(np.float32)
    eta = rng.uniform(0, 1.5, size=(t,)).astype(np.float32)
    mixers = rng.integers(1, 2**31 - 1, size=(2, t, d)).astype(np.int32) | 1
    out_k = lh.lsh_hash(x, eta, mixers, inv_cell=1 / 1.5, block_n=64, interpret=True)
    out_r = ref.lsh_hash(jnp.asarray(x), jnp.asarray(eta), jnp.asarray(mixers), 1 / 1.5)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_lsh_hash_same_bucket_iff_same_code():
    """Points < cell apart that share a cell must share keys; far points
    must (w.h.p.) not."""
    rng = np.random.default_rng(0)
    d, t = 8, 6
    eps = 0.5
    base = rng.normal(size=(1, d)).astype(np.float32)
    near = base + 1e-5
    far = base + 10.0
    x = np.concatenate([base, near, far]).astype(np.float32)
    eta = rng.uniform(0, 2 * eps, size=(t,)).astype(np.float32)
    mixers = rng.integers(1, 2**31 - 1, size=(2, t, d)).astype(np.int32) | 1
    keys = np.asarray(lh.lsh_hash(x, eta, mixers, inv_cell=1 / (2 * eps), interpret=True))
    assert (keys[0] == keys[1]).all()
    assert not (keys[0] == keys[2]).all()


# --------------------------------------------------------------------- #
# eps_neighbor_counts
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("n,d", [(50, 3), (130, 8), (257, 16)])
def test_pairwise_counts_match_ref(n, d):
    rng = np.random.default_rng(n * d)
    x = (rng.normal(size=(n, d)) * 0.7).astype(np.float32)
    eps = 0.8
    out_k = pd.eps_neighbor_counts(x, eps=eps, block_m=64, block_n=64, interpret=True)
    out_r = ref.eps_neighbor_counts(jnp.asarray(x), eps)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_pairwise_counts_match_exact_numpy():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(80, 5)).astype(np.float32)
    eps = 1.0
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    exact = (d2 <= eps * eps + 1e-6).sum(-1)
    out = pd.eps_neighbor_counts(x, eps=eps, block_m=32, block_n=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), exact)


# --------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,dh,causal,window",
    [
        (1, 2, 2, 64, 64, 32, True, None),
        (2, 4, 2, 128, 128, 64, True, None),
        (1, 4, 1, 96, 96, 32, True, None),       # MQA, non-multiple seq
        (1, 2, 2, 64, 64, 32, True, 16),         # sliding window
        (2, 2, 2, 1, 128, 32, True, None),       # decode: 1 query token
        (1, 2, 2, 64, 64, 32, False, None),      # bidirectional (encoder)
    ],
)
def test_flash_attention_matches_ref(b, hq, hkv, sq, skv, dh, causal, window):
    rng = np.random.default_rng(hq * sq + skv + dh)
    q = rng.normal(size=(b, hq, sq, dh)).astype(np.float32)
    k = rng.normal(size=(b, hkv, skv, dh)).astype(np.float32)
    v = rng.normal(size=(b, hkv, skv, dh)).astype(np.float32)
    q_off = skv - sq if causal else 0
    out_k = fa.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_off,
        block_q=32, block_k=32, interpret=True,
    )
    out_r = ref.attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, q_offset=q_off,
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32)), dtype=dtype)
    out_k = fa.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    out_r = ref.attention(q, k, v)
    assert out_k.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out_k, dtype=np.float32),
        np.asarray(out_r, dtype=np.float32),
        atol=tol, rtol=tol,
    )


def test_flash_attention_long_decode_row():
    """Decode shape: one query against a long KV with GQA grouping."""
    rng = np.random.default_rng(1)
    q = rng.normal(size=(2, 8, 1, 64)).astype(np.float32)
    k = rng.normal(size=(2, 2, 512, 64)).astype(np.float32)
    v = rng.normal(size=(2, 2, 512, 64)).astype(np.float32)
    out_k = fa.flash_attention(
        q, k, v, q_offset=511, block_q=1, block_k=128, interpret=True
    )
    out_r = ref.attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), q_offset=511)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=2e-5, rtol=2e-5)
