"""Tests for the sampled-core tier (backend="approx") and the tiered
serving index (backend="tiered"): rate=1.0 oracle equivalence against
the exact SoA engine, ARI floors at real sampling rates, the rescaled
core threshold k_s = max(1, round(k * rate)), deterministic splitmix64
sampling, sharded composition (S in {1, 2, 4} and the process
transport), and the async verifier's divergence gauge in an obs
snapshot."""

import numpy as np
import pytest

from repro.api import ClusterConfig, build_index, restore_index
from repro.core import adjusted_rand_index
from repro.core.approx import SampledCoreDBSCAN, is_sampled, sampled_mask
from repro.data import blobs

from test_api import assert_same_partition


def cfg8(**kw):
    base = dict(d=8, k=24, t=8, eps=0.5, seed=0)
    base.update(kw)
    return ClusterConfig(**base)


def stream(idx, X, batch=200, window=None, drop_every=2):
    """Insert X in batches with periodic sliding-window deletions."""
    rng = np.random.default_rng(7)
    ids, ptr = [], 0
    for bno, s in enumerate(range(0, len(X), batch)):
        ids += idx.insert_batch(X[s:s + batch])
        if window and len(ids) - ptr > window and bno % drop_every == 0:
            drop = len(ids) - ptr - window
            idx.delete_batch(ids[ptr:ptr + drop])
            ptr += drop
    live = ids[ptr:]
    return live, idx.labels(live)


# ---------------------------------------------------------------------- #
# deterministic sampling
# ---------------------------------------------------------------------- #
def test_sampled_mask_matches_scalar_and_is_deterministic():
    ids = np.arange(0, 5000, dtype=np.int64)
    for rate, seed in [(0.1, 0), (0.3, 5), (0.5, 123)]:
        m = sampled_mask(ids, rate, seed)
        assert m.dtype == bool and m.shape == ids.shape
        scalar = np.array([is_sampled(int(i), rate, seed) for i in ids])
        assert np.array_equal(m, scalar)
        assert np.array_equal(m, sampled_mask(ids, rate, seed))
        # unbiased: the sampled fraction tracks the rate
        assert abs(m.mean() - rate) < 0.03
    assert sampled_mask(ids, 1.0, 0).all()
    assert not sampled_mask(ids, 0.0, 0).any()
    # the seed reshuffles which ids are sampled
    assert not np.array_equal(sampled_mask(ids, 0.3, 0),
                              sampled_mask(ids, 0.3, 1))


def test_core_threshold_is_rescaled_to_the_sample():
    # k_s = max(1, round(k * rate)) — DBSCAN++'s minPts rescaling — so
    # the sampled count stays an unbiased estimate of ">= k neighbors"
    for k, rate, want in [(24, 0.1, 2), (24, 1.0, 24), (256, 0.1, 26),
                          (10, 0.05, 1), (8, 0.25, 2)]:
        eng = SampledCoreDBSCAN(d=4, k=k, t=4, eps=0.5, seed=0,
                                sample_rate=rate, use_device=False)
        assert eng.core_k == want
    # the exact engine keeps core_k == k (the degenerate rescaling)
    from repro.core.soa import SoADynamicDBSCAN
    assert SoADynamicDBSCAN(d=4, k=24, t=4, eps=0.5, seed=0,
                            use_device=False).core_k == 24


# ---------------------------------------------------------------------- #
# rate=1.0 oracle: bit-identical to the exact engine
# ---------------------------------------------------------------------- #
def test_approx_at_rate_one_is_bit_identical_to_soa():
    X, _ = blobs(n=900, d=8, n_clusters=4, cluster_std=0.3, seed=2)
    cfg = cfg8(sample_rate=1.0)
    A = build_index(cfg.replace(backend="soa"))
    B = build_index(cfg.replace(backend="approx"))
    rng = np.random.default_rng(0)
    alive = []
    for s in range(0, len(X), 150):
        assert A.insert_batch(X[s:s + 150]) == \
            (got := B.insert_batch(X[s:s + 150]))
        alive += got
        assert sorted(A.drain_deltas()) == sorted(B.drain_deltas())
        if len(alive) > 200:
            dels = [alive.pop(int(rng.integers(len(alive))))
                    for _ in range(40)]
            A.delete_batch(dels)
            B.delete_batch(dels)
            assert sorted(A.drain_deltas()) == sorted(B.drain_deltas())
        assert A.labels() == B.labels()  # identical dicts, not just ARI
    A.check_invariants()
    B.check_invariants()


def test_approx_snapshot_restore_roundtrip():
    X, _ = blobs(n=600, d=8, n_clusters=4, cluster_std=0.3, seed=4)
    ix = build_index(cfg8(backend="approx", sample_rate=0.3))
    ix.insert_batch(X[:400])
    ix.delete_batch(list(ix.ids())[::4])
    snap = ix.snapshot()
    clone = restore_index(snap)
    assert clone.labels() == ix.labels()
    ix.insert_batch(X[400:])
    clone.insert_batch(X[400:])
    assert clone.labels() == ix.labels()
    clone.check_invariants()


# ---------------------------------------------------------------------- #
# quality floors at real sampling rates
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("rate", [0.1, 0.3])
def test_approx_ari_floor_vs_exact(rate):
    X, _ = blobs(n=3000, d=8, n_clusters=4, cluster_std=0.4, seed=3)
    cfg = cfg8(k=64)  # dense buckets so k_s = round(64 * rate) >= 6
    _, exact = stream(build_index(cfg.replace(backend="soa")),
                      X, window=2000)
    _, got = stream(build_index(cfg.replace(backend="approx",
                                            sample_rate=rate)),
                    X, window=2000)
    common = sorted(set(exact) & set(got))
    ari = adjusted_rand_index([exact[i] for i in common],
                              [got[i] for i in common])
    assert ari >= 0.9, (rate, ari)


# ---------------------------------------------------------------------- #
# sharded composition
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_approx_matches_unsharded(shards):
    X, _ = blobs(n=800, d=8, n_clusters=4, cluster_std=0.3, seed=5)
    cfg = cfg8(backend="approx", sample_rate=0.3)
    ref = build_index(cfg)
    shd = build_index(cfg.with_shards(shards))
    _, want = stream(ref, X, window=500)
    _, got = stream(shd, X, window=500)
    # same sampled set (id-hash sampling is placement-independent), same
    # partition; labels may differ by anchor renaming across shards
    assert_same_partition(want, got)
    shd.close()


def test_sharded_approx_process_transport():
    X, _ = blobs(n=400, d=8, n_clusters=4, cluster_std=0.3, seed=6)
    cfg = cfg8(backend="approx", sample_rate=0.3, transport="process")
    ref = build_index(cfg8(backend="approx", sample_rate=0.3))
    shd = build_index(cfg.with_shards(2))
    try:
        _, want = stream(ref, X)
        _, got = stream(shd, X)
        assert_same_partition(want, got)
    finally:
        shd.close()


# ---------------------------------------------------------------------- #
# tiered serving index
# ---------------------------------------------------------------------- #
def test_tiered_serves_from_front_and_verifies_on_back():
    X, _ = blobs(n=1500, d=8, n_clusters=4, cluster_std=0.4, seed=8)
    cfg = cfg8(k=64, backend="tiered", sample_rate=0.2, obs=True)
    idx = build_index(cfg)
    try:
        live, served = stream(idx, X, window=1000)
        # the front tier answers immediately for every live point
        assert sorted(served) == sorted(live)
        # after the barrier the back tier has applied the whole stream
        exact = idx.exact_labels(live)
        assert sorted(exact) == sorted(live)
        common = sorted(live)
        ari = adjusted_rand_index([exact[i] for i in common],
                                  [served[i] for i in common])
        assert ari >= 0.9, ari

        # divergence is tracked in the obs snapshot (the serving-side
        # contract: dashboards read this gauge, tests pin its presence)
        snap = idx.obs.snapshot()
        m = snap["metrics"]
        assert m["tiered.divergence_ari"]["type"] == "gauge"
        assert m["tiered.divergence_ari"]["value"] >= 0.9
        assert m["tiered.lag"]["value"] == 0  # flushed by exact_labels()
        assert "tiered.queue_depth" in m and "tiered.hot_buckets" in m
        idx.check_invariants()
    finally:
        idx.close()


def test_tiered_at_rate_one_front_equals_back():
    X, _ = blobs(n=500, d=8, n_clusters=4, cluster_std=0.3, seed=9)
    idx = build_index(cfg8(backend="tiered", sample_rate=1.0))
    try:
        ids = idx.insert_batch(X)
        idx.delete_batch(ids[::5])
        live = [i for j, i in enumerate(ids) if j % 5]
        assert idx.labels(live) == idx.exact_labels(live)
    finally:
        idx.close()


def test_tiered_snapshot_restore_roundtrip():
    X, _ = blobs(n=400, d=8, n_clusters=4, cluster_std=0.3, seed=10)
    idx = build_index(cfg8(backend="tiered", sample_rate=0.3))
    try:
        idx.insert_batch(X[:300])
        snap = idx.snapshot()
        clone = restore_index(snap)
        try:
            assert clone.labels() == idx.labels()
            idx.insert_batch(X[300:])
            clone.insert_batch(X[300:])
            assert clone.labels() == idx.labels()
            assert clone.exact_labels() == idx.exact_labels()
        finally:
            clone.close()
    finally:
        idx.close()
