"""Distributed integration tests: build_cell lower+compile (and run) on an
8-device host mesh.  Runs in a subprocess because the placeholder device
count must be set before jax initialises (the main test process keeps 1
device, as required)."""

import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.cells import build_cell
from repro.launch.hlo_analysis import analyze_compiled

mesh = jax.make_mesh((4, 2), ("data", "model"))
out = {}

def run(arch, shape_kind, execute=False):
    cfg = get_config(arch).smoke()
    if shape_kind == "train":
        shape = ShapeConfig("t", 32, 8, "train")
    elif shape_kind == "prefill":
        shape = ShapeConfig("p", 64, 4, "prefill")
    else:
        shape = ShapeConfig("d", 64, 8, "decode")
    cell = build_cell(arch, shape.name, mesh, cfg=cfg, shape=shape,
                      grad_accum=2 if shape_kind == "train" else None)
    lowered = cell.lower()
    compiled = lowered.compile()
    rec = analyze_compiled(compiled)
    assert rec["flops_per_device"] > 0
    assert rec["hbm_bytes_per_device"] > 0
    if execute:
        # materialise real inputs from the ShapeDtypeStructs and run 1 step
        def make(x, key=[0]):
            if x.dtype == jnp.int32:
                if x.shape == ():
                    return jnp.asarray(0, jnp.int32)
                return jnp.zeros(x.shape, jnp.int32)
            key[0] += 1
            # non-negative so Adam's second-moment stays valid
            return jnp.abs(
                jax.random.normal(jax.random.PRNGKey(key[0]), x.shape, jnp.float32)
            ).astype(x.dtype) * 0.02
        args = jax.tree.map(make, cell.args)
        res = cell.run(*args)
        flat = jax.tree.leaves(res)
        for l in flat:
            assert np.isfinite(np.asarray(l, np.float32)).all()
    return rec

results = {}
results["dense_train"] = run("granite-20b", "train", execute=True)
results["moe_train"] = run("dbrx-132b", "train", execute=True)
results["ssm_train"] = run("mamba2-780m", "train", execute=True)
results["hybrid_train"] = run("hymba-1.5b", "train")
results["audio_train"] = run("whisper-small", "train")
results["vlm_train"] = run("llava-next-mistral-7b", "train")
results["gemma_train"] = run("gemma3-27b", "train")
results["dense_prefill"] = run("phi3-mini-3.8b", "prefill")
results["dense_decode"] = run("qwen1.5-110b", "decode", execute=True)
results["gemma_decode"] = run("gemma3-27b", "decode", execute=True)
results["ssm_decode"] = run("mamba2-780m", "decode", execute=True)
results["moe_decode"] = run("granite-moe-1b-a400m", "decode")
results["hybrid_decode"] = run("hymba-1.5b", "decode")
results["audio_decode"] = run("whisper-small", "decode")
print("RESULTS" + json.dumps({k: v["flops_per_device"] for k, v in results.items()}))
"""


@pytest.mark.slow
def test_cells_compile_and_run_on_host_mesh():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        cwd=Path(__file__).resolve().parents[1],
        capture_output=True, text=True, timeout=3000,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nstdout:\n{proc.stdout[-4000:]}\n"
            f"stderr:\n{proc.stderr[-6000:]}"
        )
    assert "RESULTS" in proc.stdout
