"""Tests for repro.shard: LSH key-range routing, the sharded ClusterIndex,
cross-shard cluster merging, snapshot/rebalance, and the acceptance
criterion — on mixed Insert/Delete streams a ShardedIndex (S ∈ {2, 4},
inner ∈ {dynamic, batched}) yields the same canonical partition as the
single-shard inner backend, including clusters spanning shard boundaries
and after a snapshot()/restore() and a rebalance() mid-stream."""

import numpy as np
import pytest

from repro.api import (
    ClusterConfig,
    build_index,
    register_backend,
    restore_index,
    unregister_backend,
)
from repro.core.hashing import GridLSH
from repro.data import blobs
from repro.shard import (
    SLOTS,
    RebalancePlan,
    ShardedIndex,
    ShardRouter,
    propose_rebalance,
    shard_loads,
)

from test_api import assert_same_partition, mixed_stream


def sharded_cfg(shards, inner="dynamic", **kw):
    base = dict(d=4, k=8, t=8, eps=0.45, seed=0, backend="sharded")
    base.update(kw)
    return ClusterConfig(shards=shards, inner_backend=inner, **base)


# ---------------------------------------------------------------------- #
# router
# ---------------------------------------------------------------------- #
def test_router_is_deterministic_and_covers_all_shards():
    lsh = GridLSH(4, 0.45, 8, seed=0)
    X, _ = blobs(n=2000, d=4, n_clusters=20, cluster_std=0.3, seed=0)
    a = ShardRouter(lsh, 4, seed=0).shards_batch(X)
    b = ShardRouter(lsh, 4, seed=0).shards_batch(X)
    assert np.array_equal(a, b)  # same config -> same routing
    assert set(np.unique(a)) == {0, 1, 2, 3}
    # single-point routing agrees with the batch path
    r = ShardRouter(lsh, 4, seed=0)
    assert r.shard_of(X[17]) == a[17]
    # a different seed gives a different slot hash
    c = ShardRouter(lsh, 4, seed=1).shards_batch(X)
    assert not np.array_equal(a, c)


def test_router_ranges_partition_the_slot_space():
    lsh = GridLSH(3, 0.5, 4, seed=0)
    r = ShardRouter(lsh, 4, seed=0)
    ranges = r.ranges()
    assert ranges[0][0] == 0 and ranges[-1][1] == SLOTS
    for (_, stop, _), (start, _, _) in zip(ranges, ranges[1:]):
        assert stop == start
    assert {s for _, _, s in ranges} == {0, 1, 2, 3}


def test_router_move_range_and_validation():
    lsh = GridLSH(3, 0.5, 4, seed=0)
    r = ShardRouter(lsh, 2, seed=0)
    r.move_range(RebalancePlan(0, 100, 1))
    assert (r.assignment[:100] == 1).all()
    with pytest.raises(ValueError, match="slot range"):
        r.move_range(RebalancePlan(10, 5, 0))
    with pytest.raises(ValueError, match="target shard"):
        r.move_range(RebalancePlan(0, 10, 7))


# ---------------------------------------------------------------------- #
# config / registry plumbing
# ---------------------------------------------------------------------- #
def test_sharded_config_validation():
    with pytest.raises(ValueError, match="shards"):
        ClusterConfig(d=3, k=2, t=2, eps=0.5, shards=0)
    with pytest.raises(ValueError, match="inner_backend"):
        ClusterConfig(d=3, k=2, t=2, eps=0.5, inner_backend="sharded")


@pytest.mark.parametrize("inner", ["naive", "emz-fixed"])
def test_unsupported_inner_backends_rejected(inner):
    with pytest.raises(ValueError, match="cannot be sharded"):
        build_index(sharded_cfg(2, inner=inner))


def test_custom_inner_backend_via_registry_swap():
    """register_backend(overwrite=True)/unregister_backend let tests plug
    a custom factory in as the sharded inner engine."""
    calls = []

    @register_backend("test-inner")
    def _build(cfg):
        calls.append(cfg.backend)
        from repro.api.backends import _build_dynamic
        return _build_dynamic(cfg)

    try:
        with pytest.raises(ValueError, match="overwrite"):
            register_backend("test-inner")(_build)
        register_backend("test-inner", overwrite=True)(_build)

        X, _ = blobs(n=100, d=4, n_clusters=2, cluster_std=0.15, seed=0)
        index = build_index(sharded_cfg(2, inner="test-inner"))
        index.insert_batch(X)
        assert len(calls) == 2  # one factory call per shard
        assert len(index) == 100
    finally:
        unregister_backend("test-inner")
    with pytest.raises(KeyError, match="test-inner"):
        unregister_backend("test-inner")


# ---------------------------------------------------------------------- #
# mutation semantics match the single-shard contract
# ---------------------------------------------------------------------- #
def test_sharded_handle_assignment_matches_single_shard():
    X, _ = blobs(n=20, d=4, n_clusters=2, seed=1)
    index = build_index(sharded_cfg(3))
    assert index.insert(X[0], idx=17) == 17
    with pytest.raises(KeyError):
        index.insert(X[1], idx=17)
    assert index.insert_batch(X[1:4], ids=[None, 99, None]) == [18, 99, 100]
    with pytest.raises(KeyError):
        index.delete(12345)
    with pytest.raises(ValueError, match="shape"):
        index.insert(np.zeros(7))
    with pytest.raises(ValueError, match="shape"):
        index.insert_batch(np.zeros((3, 7)))


def test_sharded_delete_batch_rejects_duplicates_before_mutating():
    X, _ = blobs(n=30, d=4, n_clusters=2, seed=1)
    index = build_index(sharded_cfg(2))
    ids = index.insert_batch(X)
    with pytest.raises(KeyError, match=f"duplicate id {ids[3]}"):
        index.delete_batch([ids[0], ids[3], ids[5], ids[3]])
    assert len(index) == 30  # nothing was removed
    with pytest.raises(KeyError):
        index.delete_batch([ids[0], 99999])
    assert len(index) == 30
    index.delete_batch(ids[:10])
    assert len(index) == 20
    index.check_invariants()


# ---------------------------------------------------------------------- #
# cross-shard equivalence (acceptance criterion)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("inner", ["dynamic", "batched", "emz-static"])
@pytest.mark.parametrize("shards", [2, 4])
def test_insert_stream_matches_single_shard(shards, inner):
    X, _ = blobs(n=350, d=4, n_clusters=4, cluster_std=0.15, seed=0)
    ref = build_index(sharded_cfg(shards).replace(backend=inner))
    ref.insert_batch(X)
    sh = build_index(sharded_cfg(shards, inner=inner))
    sh.insert_batch(X)
    sh.check_invariants()
    assert_same_partition(ref.labels(), sh.labels())


@pytest.mark.parametrize("inner", ["dynamic", "batched"])
@pytest.mark.parametrize("shards", [2, 4])
@pytest.mark.parametrize("seed", [0, 1])
def test_mixed_stream_with_snapshot_and_rebalance_matches_single_shard(
        seed, shards, inner):
    """The PR's acceptance test: mixed Insert/Delete stream, snapshot/
    restore + rebalance mid-stream, then compare the final partition
    against the single-shard inner backend."""
    events = mixed_stream(n=400, d=4, seed=seed)
    ref = build_index(ClusterConfig(d=4, k=8, t=8, eps=0.45, seed=seed,
                                    backend=inner))
    ref.apply(events)

    sh = build_index(sharded_cfg(shards, inner=inner, seed=seed))
    half = len(events) // 2
    sh.apply(events[:half])

    # snapshot/restore round-trip mid-stream
    sh = restore_index(sh.snapshot())
    sh.check_invariants()

    # rebalance mid-stream: the global partition must not move
    before = sh.labels()
    plan = propose_rebalance(sh)
    if plan is not None:
        moved = sh.rebalance(plan)["moved"]
        assert moved > 0
    sh.check_invariants()
    assert_same_partition(before, sh.labels())

    sh.apply(events[half:])
    sh.check_invariants()
    assert_same_partition(ref.labels(), sh.labels())


def test_clusters_spanning_shard_boundaries_are_merged():
    """Force every consecutive pair of a dense line of points onto
    alternating shards-by-construction: the bridge must still report one
    cluster, and the boundary directory must see cross-shard buckets."""
    cfg = sharded_cfg(4, d=2, k=3, t=4, eps=0.5, seed=0)
    index = build_index(cfg)
    # a tight line of points spread over many grid cells -> many shards
    X = np.stack([np.linspace(0, 30, 120), np.zeros(120)], axis=1)
    X += 0.01 * np.random.default_rng(0).normal(size=X.shape)
    index.insert_batch(X)
    index.check_invariants()
    assert len(set(shard_loads(index).tolist())) >= 1
    assert shard_loads(index).min() > 0  # points really did spread out
    lab = index.labels()
    assert len({v for v in lab.values() if v != -1}) == 1  # one cluster
    assert index.stats()["n_boundary_buckets"] > 0
    # incremental path: labels() chained only the maintained boundary set
    assert index.stats()["n_interesting_buckets"] > 0
    assert index.stats()["n_boundary_merges"] >= 1
    assert index.stats()["n_merge_passes"] == 0
    # rebuild path: the same stream exercises the merge-pass chains
    rebuild = build_index(cfg.replace(incremental_merge=False))
    rebuild.insert_batch(X)
    assert_same_partition(rebuild.labels(), lab)
    assert rebuild.stats()["n_bridge_unions"] > 0
    # and it matches the unsharded reference exactly
    ref = build_index(cfg.replace(backend="dynamic"))
    ref.insert_batch(X)
    assert_same_partition(ref.labels(), lab)


def test_attach_orphans_false_is_respected():
    """With re-attachment disabled the bridge must not quietly glue
    orphaned non-core points back onto remote cores: the noise set has to
    match the single-shard engine's."""
    events = mixed_stream(n=300, d=4, seed=11, p_delete=0.35)
    cfg = ClusterConfig(d=4, k=8, t=8, eps=0.45, seed=11,
                        attach_orphans=False)
    ref = build_index(cfg)
    ref.apply(events)
    sh = build_index(cfg.replace(backend="sharded", shards=3))
    sh.apply(events)
    assert sh.bridge.attach_orphans is False
    ref_noise = {i for i, v in ref.labels().items() if v == -1}
    sh_noise = {i for i, v in sh.labels().items() if v == -1}
    assert ref_noise == sh_noise


def test_config_with_shards_convention():
    cfg = ClusterConfig(d=4, k=8, t=8, eps=0.45)
    assert cfg.with_shards(0) is cfg
    assert cfg.with_shards(1) is cfg
    wrapped = cfg.replace(backend="batched").with_shards(4)
    assert (wrapped.backend, wrapped.shards, wrapped.inner_backend) == \
        ("sharded", 4, "batched")
    # already sharded: only the count (and optionally the inner) changes
    again = wrapped.with_shards(2)
    assert (again.backend, again.shards, again.inner_backend) == \
        ("sharded", 2, "batched")
    assert wrapped.with_shards(0).shards == 1
    assert cfg.with_shards(3, inner="emz-static").inner_backend == "emz-static"


def test_label_and_is_core_agree_with_reference():
    X, _ = blobs(n=250, d=4, n_clusters=3, cluster_std=0.15, seed=2)
    ref = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.45, seed=2))
    sh = build_index(sharded_cfg(3, k=6, t=6, seed=2))
    ref.insert_batch(X)
    ids = sh.insert_batch(X)
    for i in ids[::25]:
        assert sh.is_core(i) == ref.is_core(i)
        co = [j for j in ids if sh.label(j) == sh.label(i)]
        co_ref = [j for j in ids if ref.label(j) == ref.label(i)]
        assert co == co_ref
    with pytest.raises(KeyError):
        sh.label(10**9)


# ---------------------------------------------------------------------- #
# rebalance
# ---------------------------------------------------------------------- #
def test_rebalance_moves_a_key_range_and_preserves_everything():
    X, _ = blobs(n=300, d=4, n_clusters=4, cluster_std=0.15, seed=3)
    sh = build_index(sharded_cfg(2, inner="batched", seed=3))
    ids = sh.insert_batch(X)
    before_labels = sh.labels()
    before_loads = shard_loads(sh).copy()
    # move the whole first half of the slot space to shard 1
    out = sh.rebalance(RebalancePlan(0, SLOTS // 2, 1))
    sh.check_invariants()
    assert out["moved"] > 0
    loads = shard_loads(sh)
    assert loads[1] == before_loads[1] + out["moved"]
    assert loads[0] == before_loads[0] - out["moved"]
    assert sh.labels() == before_labels  # identical, not just isomorphic
    assert sh.ids() == sorted(ids)
    # moving everything to shard 0 empties shard 1
    sh.rebalance((0, SLOTS, 0))
    assert shard_loads(sh).tolist() == [300, 0]
    sh.check_invariants()
    assert_same_partition(sh.labels(), before_labels)


def test_propose_rebalance_narrows_the_load_gap():
    X, _ = blobs(n=400, d=4, n_clusters=2, cluster_std=0.1, seed=4)
    sh = build_index(sharded_cfg(4, seed=4))
    sh.insert_batch(X)
    for _ in range(8):
        plan = propose_rebalance(sh)
        if plan is None:
            break
        gap_before = int(shard_loads(sh).max() - shard_loads(sh).min())
        sh.rebalance(plan)
        gap_after = int(shard_loads(sh).max() - shard_loads(sh).min())
        assert gap_after < gap_before
    sh.check_invariants()


# ---------------------------------------------------------------------- #
# persistence
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("inner", ["dynamic", "batched", "emz-static"])
def test_sharded_snapshot_roundtrip(inner):
    events = mixed_stream(n=300, d=4, seed=5)
    sh = build_index(sharded_cfg(3, inner=inner, seed=5))
    sh.apply(events)
    back = restore_index(sh.snapshot())
    assert isinstance(back, ShardedIndex)
    back.check_invariants()
    assert back.labels() == sh.labels()
    assert back.ids() == sh.ids()
    assert shard_loads(back).tolist() == shard_loads(sh).tolist()
    # restored index stays live and keeps routing consistently
    new = back.insert(np.zeros(4))
    assert new not in sh
    back.delete(new)
    assert back.labels() == sh.labels()


def test_sharded_snapshot_preserves_rebalanced_assignment():
    X, _ = blobs(n=200, d=4, n_clusters=3, cluster_std=0.15, seed=6)
    sh = build_index(sharded_cfg(2, seed=6))
    sh.insert_batch(X)
    sh.rebalance(RebalancePlan(0, SLOTS // 4, 1))
    back = restore_index(sh.snapshot())
    assert np.array_equal(back.router.assignment, sh.router.assignment)
    assert shard_loads(back).tolist() == shard_loads(sh).tolist()
    back.check_invariants()


def test_sharded_through_checkpoint_manager(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    events = mixed_stream(n=250, d=4, seed=7)
    sh = build_index(sharded_cfg(2, inner="batched", seed=7))
    sh.apply(events)
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_index(1, sh)
    back = mgr.restore_index()
    back.check_invariants()
    assert back.cfg == sh.cfg
    assert back.labels() == sh.labels()


def test_empty_sharded_index():
    sh = build_index(sharded_cfg(4))
    assert len(sh) == 0 and sh.ids() == [] and sh.labels() == {}
    back = restore_index(sh.snapshot())
    assert len(back) == 0
    back.check_invariants()
