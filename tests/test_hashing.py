"""Property tests for the grid-LSH family (Lemma 1 of the paper)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.hashing import GridLSH


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    d=st.integers(1, 8),
)
def test_lemma1_part2_same_bucket_implies_linf_bound(seed, d):
    """h(x) = h(y) ⟹ ||x - y||_inf <= 2 eps."""
    rng = np.random.default_rng(seed)
    eps = float(rng.uniform(0.1, 2.0))
    lsh = GridLSH(d, eps, t=4, seed=seed)
    x = rng.normal(size=d) * 3
    y = rng.normal(size=d) * 3
    kx, ky = lsh.keys(x), lsh.keys(y)
    for i in range(4):
        if kx[i] == ky[i]:
            assert np.max(np.abs(x - y)) <= 2 * eps + 1e-9


def test_lemma1_part1_collision_probability():
    """Pr[h(x)=h(y)] >= 1 - ||x-y||_1 / (2 eps), estimated over many
    independent eta draws."""
    rng = np.random.default_rng(0)
    d, eps = 4, 1.0
    x = rng.normal(size=d)
    for dist_scale in (0.05, 0.2, 0.5):
        y = x + rng.uniform(-1, 1, size=d) * dist_scale
        l1 = np.abs(x - y).sum()
        if l1 >= 2 * eps:
            continue
        hits = 0
        trials = 400
        for s in range(trials):
            lsh = GridLSH(d, eps, t=1, seed=s)
            hits += lsh.keys(x)[0] == lsh.keys(y)[0]
        p_hat = hits / trials
        lower = 1 - l1 / (2 * eps)
        # allow 3-sigma sampling slack
        sigma = np.sqrt(max(lower * (1 - lower), 0.01) / trials)
        assert p_hat >= lower - 3 * sigma, (p_hat, lower)


def test_identical_points_always_collide():
    lsh = GridLSH(6, 0.5, t=8, seed=1)
    x = np.random.default_rng(2).normal(size=6)
    assert lsh.keys(x) == lsh.keys(x.copy())


def test_device_keys_consistent_with_exact_keys():
    """Mixed-key (kernel) path must group points identically to the exact
    path wherever the exact codes agree (f32 grid edges may differ)."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(500, 8))
    lsh = GridLSH(8, 0.6, t=5, seed=3)
    exact = lsh.codes_batch(X)          # (n, t, d) f64 codes
    mixed = lsh.device_keys_batch(X)    # (n, t, 2) int32 keys
    f32_codes = np.floor(
        (X.astype(np.float32)[:, None, :]
         + lsh.eta.astype(np.float32)[None, :, None])
        * np.float32(lsh.inv_cell)
    ).astype(np.int64)
    for i in range(5):
        _, ex_inv = np.unique(f32_codes[:, i, :], axis=0, return_inverse=True)
        _, mx_inv = np.unique(
            mixed[:, i, :].view(np.int64).reshape(-1), return_inverse=True
        )
        # identical partitions of the 500 points
        pairs = {}
        for a, b in zip(ex_inv, mx_inv):
            assert pairs.setdefault(a, b) == b
        rpairs = {}
        for a, b in zip(mx_inv, ex_inv):
            assert rpairs.setdefault(a, b) == b


def test_batch_and_single_agree():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(50, 5))
    lsh = GridLSH(5, 0.4, t=6, seed=4)
    batch = lsh.keys_batch(X)
    for j in range(50):
        assert batch[j] == lsh.keys(X[j])
