"""Property-based tests (hypothesis) for the system's invariants.

Invariants under arbitrary update sequences (paper §4.2 / Thm 2):
  * support counts == exact Definition-4 core rule;
  * G[C] is a spanning forest of H (per-bucket chain connectivity);
  * non-core degree <= 1;
  * core partition equals a from-scratch EMZ recompute;
  * the structure is oblivious to update order (H order-invariance).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import DynamicDBSCAN, GridLSH, NOISE, emz_cluster


def _apply_ops(dyn, ops):
    alive = {}
    serial = 0
    for op, payload in ops:
        if op == "add":
            idx = dyn.add_point(np.array(payload))
            alive[idx] = np.array(payload)
            serial += 1
        elif op == "del" and alive:
            keys = sorted(alive.keys())
            victim = keys[payload % len(keys)]
            dyn.delete_point(victim)
            del alive[victim]
    return alive


ops_strategy = st.lists(
    st.one_of(
        st.tuples(
            st.just("add"),
            st.tuples(
                st.integers(-6, 6).map(lambda v: v / 3.0),
                st.integers(-6, 6).map(lambda v: v / 3.0),
            ),
        ),
        st.tuples(st.just("del"), st.integers(0, 10**6)),
    ),
    min_size=1,
    max_size=120,
)


@settings(max_examples=40, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy, seed=st.integers(0, 3))
def test_invariants_hold_under_arbitrary_updates(ops, seed):
    dyn = DynamicDBSCAN(2, k=3, t=4, eps=0.5, seed=seed)
    _apply_ops(dyn, ops)
    dyn.check_invariants()


@settings(max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ops=ops_strategy, seed=st.integers(0, 3))
def test_core_partition_matches_recompute(ops, seed):
    lsh = GridLSH(2, 0.5, 4, seed=seed)
    dyn = DynamicDBSCAN(2, k=3, t=4, eps=0.5, seed=seed, lsh=lsh)
    alive = _apply_ops(dyn, ops)
    if not alive:
        return
    ids = sorted(alive.keys())
    X = np.stack([alive[i] for i in ids])
    static, score = emz_cluster(X, 3, 0.5, 4, lsh=lsh, return_core=True)
    dyn_core = np.array([dyn.is_core(i) for i in ids])
    assert np.array_equal(dyn_core, score)
    labels = dyn.labels(ids)
    la = np.array([labels[i] for i in ids])
    assert np.array_equal(la == NOISE, static == NOISE)
    # bijective cluster mapping on core points
    fw, bw = {}, {}
    for a, b in zip(la[dyn_core], static[score]):
        assert fw.setdefault(a, b) == b
        assert bw.setdefault(b, a) == a


@settings(max_examples=30, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["link", "cut"]), st.integers(0, 11), st.integers(0, 11)),
        max_size=80,
    ),
    seed=st.integers(0, 5),
)
def test_euler_tour_matches_union_find_on_links(ops, seed):
    """Forest connectivity == incremental oracle under arbitrary link/cut."""
    from repro.core import EulerTourForest

    f = EulerTourForest(seed=seed)
    adj = {v: set() for v in range(12)}
    for v in range(12):
        f.add_node(v)

    def connected(u, v):
        seen, stack = {u}, [u]
        while stack:
            x = stack.pop()
            if x == v:
                return True
            for y in adj[x]:
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        return False

    for op, u, v in ops:
        if u == v:
            continue
        if op == "link":
            expect = not connected(u, v)
            assert f.link(u, v) == expect
            if expect:
                adj[u].add(v)
                adj[v].add(u)
        else:
            expect = v in adj[u]
            assert f.cut(u, v) == expect
            adj[u].discard(v)
            adj[v].discard(u)
        for a in range(0, 12, 3):
            for b in range(1, 12, 4):
                assert f.connected(a, b) == connected(a, b)


def test_order_invariance_of_core_partition():
    """H is invariant to arrival order ⇒ core partition must be too."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(120, 2)) * 0.6
    lsh = GridLSH(2, 0.4, 5, seed=9)
    results = []
    for perm_seed in (1, 2):
        perm = np.random.default_rng(perm_seed).permutation(len(X))
        dyn = DynamicDBSCAN(2, k=4, t=5, eps=0.4, seed=9, lsh=lsh)
        id_of = {}
        for j in perm:
            id_of[j] = dyn.add_point(X[j])
        labels = dyn.labels()
        core = {j for j in range(len(X)) if dyn.is_core(id_of[j])}
        part = {}
        for j in range(len(X)):
            part.setdefault(labels[id_of[j]], set()).add(j)
        core_part = {frozenset(s & core) for s in part.values() if s & core}
        results.append((core, core_part))
    assert results[0] == results[1]
