"""Behavioural tests for DynamicDBSCAN against static oracles.

The central property (paper §4.2): H is invariant to the order of updates
and the dynamic structure's connected components equal the components of H.
With a shared LSH family, a from-scratch EMZ recompute (Definition-4 core
rule) must therefore produce the *identical partition* after any sequence
of insertions and deletions.
"""

import numpy as np
import pytest

from repro.core import (
    DynamicDBSCAN,
    GridLSH,
    NOISE,
    adjusted_rand_index,
    emz_cluster,
)
from repro.data import blobs


def _bijective(la, lb) -> bool:
    for u, v in ((la, lb), (lb, la)):
        seen = {}
        for a, b in zip(u, v):
            if seen.setdefault(a, b) != b:
                return False
    return True


def partitions_equal(labels_a: dict, labels_b: np.ndarray, ids: list) -> bool:
    """Same partition up to label renaming; noise must match exactly."""
    la = np.array([labels_a[i] for i in ids])
    lb = np.asarray(labels_b)
    noise_a = la == NOISE
    noise_b = lb == NOISE
    if not np.array_equal(noise_a, noise_b):
        return False
    if noise_a.all():
        return True
    return _bijective(la[~noise_a], lb[~noise_b])


def core_partitions_equal(dyn, labels_a: dict, labels_b: np.ndarray,
                          core_b: np.ndarray, ids: list) -> bool:
    """The paper's guarantee (Thm 2): core sets and the partition
    *restricted to core points* must match exactly; noise sets match; the
    cluster assignment of border (attached non-core) points is inherently
    order-dependent — as in classic DBSCAN — and is not compared."""
    core_a = np.array([dyn.is_core(i) for i in ids])
    if not np.array_equal(core_a, np.asarray(core_b)):
        return False
    la = np.array([labels_a[i] for i in ids])
    lb = np.asarray(labels_b)
    if not np.array_equal(la == NOISE, lb == NOISE):
        return False
    if not core_a.any():
        return True
    return _bijective(la[core_a], lb[core_a])


def make_stream(n=400, d=4, seed=0):
    X, y = blobs(n=n, d=d, n_clusters=4, cluster_std=0.3, seed=seed)
    return X, y


@pytest.mark.parametrize("seed", [0, 1])
def test_insert_matches_static_emz(seed):
    X, _ = make_stream(n=300, d=3, seed=seed)
    k, t, eps = 8, 6, 0.45
    lsh = GridLSH(3, eps, t, seed=seed)
    dyn = DynamicDBSCAN(3, k, t, eps, seed=seed, lsh=lsh)
    ids = []
    for j in range(X.shape[0]):
        ids.append(dyn.add_point(X[j]))
        if (j + 1) % 75 == 0:
            static = emz_cluster(X[: j + 1], k, eps, t, lsh=lsh)
            assert partitions_equal(dyn.labels(ids), static, ids)
            dyn.check_invariants()


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_insert_delete_matches_static_emz(seed):
    rng = np.random.default_rng(seed)
    X, _ = make_stream(n=260, d=3, seed=seed)
    k, t, eps = 6, 5, 0.5
    lsh = GridLSH(3, eps, t, seed=seed)
    dyn = DynamicDBSCAN(3, k, t, eps, seed=seed, lsh=lsh)
    alive = {}
    for j in range(X.shape[0]):
        idx = dyn.add_point(X[j])
        alive[idx] = X[j]
        if rng.random() < 0.35 and len(alive) > 5:
            victim = int(rng.choice(list(alive.keys())))
            dyn.delete_point(victim)
            del alive[victim]
        if (j + 1) % 60 == 0:
            ids = sorted(alive.keys())
            Xa = np.stack([alive[i] for i in ids])
            static, score = emz_cluster(Xa, k, eps, t, lsh=lsh, return_core=True)
            assert core_partitions_equal(dyn, dyn.labels(ids), static, score, ids)
            dyn.check_invariants()


def test_delete_everything():
    X, _ = make_stream(n=120, d=3, seed=3)
    dyn = DynamicDBSCAN(3, 5, 4, 0.5, seed=3)
    ids = [dyn.add_point(X[j]) for j in range(X.shape[0])]
    for i in ids:
        dyn.delete_point(i)
    assert len(dyn.points) == 0
    assert len(dyn.forest) == 0
    assert dyn.buckets.n_buckets() == 0


def test_get_cluster_consistent_with_labels():
    X, _ = make_stream(n=200, d=3, seed=5)
    dyn = DynamicDBSCAN(3, 6, 5, 0.5, seed=5)
    ids = [dyn.add_point(X[j]) for j in range(X.shape[0])]
    labels = dyn.labels(ids)
    roots = {i: dyn.get_cluster(i) for i in ids}
    # same root ⟺ same label, except noise (root is its own singleton)
    for a in ids[:50]:
        for b in ids[50:100]:
            if labels[a] == NOISE or labels[b] == NOISE:
                continue
            assert (roots[a] == roots[b]) == (labels[a] == labels[b])


def test_clustering_quality_on_blobs():
    """Well-separated blobs must be clustered near-perfectly (paper Table 2
    reports ARI 1.00 on blobs)."""
    X, y = blobs(n=3000, d=5, n_clusters=5, cluster_std=0.12, seed=7)
    dyn = DynamicDBSCAN(5, k=10, t=10, eps=0.35, seed=7)
    ids = [dyn.add_point(X[j]) for j in range(X.shape[0])]
    labels = dyn.labels(ids)
    pred = np.array([labels[i] for i in ids])
    ari = adjusted_rand_index(y, pred)
    assert ari > 0.95, ari


def test_deletion_reverts_structure_effects():
    """Insert base set, snapshot labels; insert extra points; delete them;
    labels must revert to the snapshot partition."""
    X, _ = make_stream(n=150, d=3, seed=11)
    extra, _ = make_stream(n=60, d=3, seed=13)
    k, t, eps = 6, 5, 0.5
    lsh = GridLSH(3, eps, t, seed=11)
    dyn = DynamicDBSCAN(3, k, t, eps, seed=11, lsh=lsh)
    ids = [dyn.add_point(X[j]) for j in range(X.shape[0])]
    before = dyn.labels(ids)
    core_before = np.array([dyn.is_core(i) for i in ids])
    extra_ids = [dyn.add_point(extra[j]) for j in range(extra.shape[0])]
    for i in extra_ids:
        dyn.delete_point(i)
    after = dyn.labels(ids)
    core_after = np.array([dyn.is_core(i) for i in ids])
    assert np.array_equal(core_before, core_after)
    la = np.array([before[i] for i in ids])
    lb = np.array([after[i] for i in ids])
    assert np.array_equal(la == NOISE, lb == NOISE)
    assert _bijective(la[core_before], lb[core_before])
    dyn.check_invariants()


def test_paper_repair_mode_is_cheaper_but_can_strand():
    """The literal Alg.-2 repair ('paper') fires no replacement scans; the
    'exact' mode does — and only 'exact' is guaranteed to match the static
    recompute after deletions (the Thm-2 gap, DESIGN.md §3)."""
    X, _ = make_stream(n=260, d=3, seed=1)
    k, t, eps = 6, 5, 0.5
    rng = np.random.default_rng(1)
    lsh = GridLSH(3, eps, t, seed=1)
    exact = DynamicDBSCAN(3, k, t, eps, lsh=lsh, repair="exact")
    paper = DynamicDBSCAN(3, k, t, eps, lsh=lsh, repair="paper")
    alive = []
    for j in range(X.shape[0]):
        exact.add_point(X[j], idx=j)
        paper.add_point(X[j], idx=j)
        alive.append(j)
        if rng.random() < 0.35 and len(alive) > 5:
            v = alive.pop(int(rng.integers(len(alive))))
            exact.delete_point(v)
            paper.delete_point(v)
    assert paper.n_repair_scans == 0
    assert exact.n_repair_scans > 0
    # exact matches the static oracle; we don't assert paper mismatches
    # (it depends on the stream), only that exact always holds
    Xa = np.stack([X[i] for i in alive])
    static, score = emz_cluster(Xa, k, eps, t, lsh=lsh, return_core=True)
    assert core_partitions_equal(exact, exact.labels(alive), static, score, alive)
