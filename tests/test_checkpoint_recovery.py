"""Crash recovery for CheckpointManager.save_index: the write protocol is
temp dir -> atomic rename -> LATEST_INDEX pointer flip.  A crash at any
point before the pointer flip must leave the previous snapshot as the
restore point, and a later save must succeed despite the debris."""

import pathlib

import numpy as np
import pytest

from repro.api import ClusterConfig, build_index
from repro.checkpoint.manager import CheckpointManager
from repro.data import blobs


def _make_index(seed=0, backend="batched"):
    X, _ = blobs(n=150, d=4, n_clusters=3, cluster_std=0.15, seed=seed)
    index = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.5, seed=seed,
                                      backend=backend))
    index.insert_batch(X)
    return index


class _Boom(RuntimeError):
    pass


def _crash_rename_on(monkeypatch, needle: str):
    """Make Path.rename raise when the *target* involves ``needle`` —
    simulates the process dying mid-save, temp dir left behind."""
    real = pathlib.Path.rename

    def rename(self, target):
        if needle in str(target):
            raise _Boom(f"simulated crash renaming to {target}")
        return real(self, target)

    monkeypatch.setattr(pathlib.Path, "rename", rename)


@pytest.mark.parametrize("crash_at", ["index_00000002", "LATEST_INDEX"])
def test_crashed_save_index_keeps_previous_snapshot(tmp_path, monkeypatch,
                                                    crash_at):
    index = _make_index()
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_index(1, index)
    labels_before = index.labels()

    # mutate, then crash while persisting step 2 (either before the final
    # directory rename or before the pointer flip)
    index.insert(np.zeros(4))
    _crash_rename_on(monkeypatch, crash_at)
    with pytest.raises(_Boom):
        mgr.save_index(2, index)
    monkeypatch.undo()

    # crash debris is visible...
    debris = (list(tmp_path.glob(".tmp_index_00000002_*"))
              + list(tmp_path.glob("LATEST_INDEX.tmp")))
    assert debris, "expected a leftover temp dir / tmp pointer"
    # ...but LATEST_INDEX still names the intact step-1 snapshot
    assert mgr.latest_index_step() == 1
    restored = mgr.restore_index()
    restored.check_invariants()
    assert restored.labels() == labels_before

    # recovery: the next save succeeds and becomes the restore point
    mgr.save_index(3, index)
    assert mgr.latest_index_step() == 3
    assert mgr.restore_index().labels() == index.labels()


def test_crash_before_first_save_means_no_checkpoint(tmp_path, monkeypatch):
    index = _make_index()
    mgr = CheckpointManager(tmp_path, async_write=False)
    _crash_rename_on(monkeypatch, "index_00000001")
    with pytest.raises(_Boom):
        mgr.save_index(1, index)
    monkeypatch.undo()
    assert mgr.latest_index_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore_index()


def test_crashed_save_applies_to_sharded_backend_too(tmp_path, monkeypatch):
    index = build_index(ClusterConfig(d=4, k=6, t=6, eps=0.5, seed=1,
                                      backend="sharded", shards=2,
                                      inner_backend="batched"))
    X, _ = blobs(n=120, d=4, n_clusters=3, cluster_std=0.15, seed=1)
    index.insert_batch(X)
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save_index(1, index)
    _crash_rename_on(monkeypatch, "index_00000002")
    with pytest.raises(_Boom):
        mgr.save_index(2, index)
    monkeypatch.undo()
    restored = mgr.restore_index()
    restored.check_invariants()
    assert restored.labels() == index.labels()
