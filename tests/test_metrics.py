"""ARI/NMI against hand-computed and well-known reference values."""

import numpy as np
import pytest

from repro.core.metrics import adjusted_rand_index, normalized_mutual_info


def test_perfect_agreement():
    a = [0, 0, 1, 1, 2, 2]
    assert adjusted_rand_index(a, a) == pytest.approx(1.0)
    assert normalized_mutual_info(a, a) == pytest.approx(1.0)


def test_label_permutation_invariance():
    a = [0, 0, 1, 1, 2, 2]
    b = [5, 5, 9, 9, 7, 7]
    assert adjusted_rand_index(a, b) == pytest.approx(1.0)
    assert normalized_mutual_info(a, b) == pytest.approx(1.0)


def test_known_ari_value():
    # classic example: sklearn.metrics.adjusted_rand_score reference
    a = [0, 0, 1, 1]
    b = [0, 0, 1, 2]
    assert adjusted_rand_index(a, b) == pytest.approx(0.5714285714, abs=1e-9)


def test_known_nmi_value():
    a = [0, 0, 1, 1]
    b = [0, 0, 1, 2]
    # by hand: MI = ln2; H(U) = ln2; H(V) = 1.5 ln2 - 0.5 ln... = 1.0397;
    # arithmetic NMI = ln2 / ((ln2 + 1.0397)/2) = 0.8
    assert normalized_mutual_info(a, b) == pytest.approx(0.8, abs=1e-6)
    # geometric variant
    assert normalized_mutual_info(a, b, average="geometric") == pytest.approx(
        0.6931 / np.sqrt(0.6931 * 1.0397), abs=1e-3
    )


def test_single_cluster_vs_many():
    a = [0] * 10
    b = list(range(10))
    assert adjusted_rand_index(a, b) == pytest.approx(0.0, abs=1e-12)


def test_random_labels_near_zero_ari():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 5, 2000)
    b = rng.integers(0, 5, 2000)
    assert abs(adjusted_rand_index(a, b)) < 0.02
    assert normalized_mutual_info(a, b) < 0.02


def test_ari_symmetry():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 4, 200)
    b = rng.integers(0, 3, 200)
    assert adjusted_rand_index(a, b) == pytest.approx(adjusted_rand_index(b, a))
    assert normalized_mutual_info(a, b) == pytest.approx(normalized_mutual_info(b, a))


def test_empty_and_singleton_streams():
    # no pair information: identical-partition convention says 1.0 for
    # both metrics (the tiered verifier diffs windows that can be empty
    # right after an expiry round — this must not divide by zero)
    assert adjusted_rand_index([], []) == pytest.approx(1.0)
    assert normalized_mutual_info([], []) == pytest.approx(1.0)
    assert adjusted_rand_index([3], [9]) == pytest.approx(1.0)


def test_all_noise_windows_agree():
    # two all-noise labellings are the same (single-block) partition
    a = [-1] * 8
    assert adjusted_rand_index(a, a) == pytest.approx(1.0)
    assert normalized_mutual_info(a, a) == pytest.approx(1.0)
    # all-noise vs one real cluster is still one block vs one block
    assert adjusted_rand_index(a, [4] * 8) == pytest.approx(1.0)


def test_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shape mismatch"):
        adjusted_rand_index([0, 1], [0])
    with pytest.raises(ValueError, match="shape mismatch"):
        normalized_mutual_info([0, 1], [0])
