"""Elastic checkpoint-restart: save on one mesh, restore on a smaller one
(simulated node failure -> re-mesh -> reshard-on-load).  Subprocess so the
placeholder device count doesn't leak into other tests."""

import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import tempfile
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.models.registry import build_model
from repro.runtime import plan_remesh
from repro.sharding import spec_tree

cfg = get_config("granite-20b").smoke()
model = build_model(cfg)
params, axes = model.init(jax.random.PRNGKey(0))

# ---- "before failure": 4x2 mesh (8 chips = 2 hosts x 4 chips) ----
mesh8 = jax.make_mesh((4, 2), ("data", "model"))
specs8 = spec_tree(axes, params, mesh8)
sharded = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh8, s)), params, specs8,
    is_leaf=lambda x: isinstance(x, P),
)
ckptdir = tempfile.mkdtemp()
mgr = CheckpointManager(ckptdir, async_write=False)
mgr.save(42, sharded)

# ---- failure: one host dies; plan the new mesh ----
plan = plan_remesh(alive_hosts=[0], chips_per_host=4, model_parallel=2,
                   global_batch=8, microbatch=2)
assert plan is not None and plan.data_parallel == 2, plan
# 2x2 mesh from the surviving 4 chips
mesh4 = jax.sharding.Mesh(
    np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model")
)
specs4 = spec_tree(axes, params, mesh4)
shardings4 = jax.tree.map(
    lambda s: NamedSharding(mesh4, s), specs4,
    is_leaf=lambda x: isinstance(x, P),
)
restored = mgr.restore(params, step=42, shardings=shardings4)

# values identical, shardings on the new mesh
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
leaf = jax.tree.leaves(restored)[0]
assert leaf.sharding.mesh.shape == {"data": 2, "model": 2}

# the restored params must actually train on the new mesh
from repro.optim import AdamW, warmup_cosine
from repro.training import make_train_step
opt = AdamW(lr=warmup_cosine(1e-3, 2, 10))
ostate = opt.init(restored)
step = jax.jit(make_train_step(model, opt, mesh=mesh4,
                               grad_accum=plan.grad_accum))
batch = {
    "tokens": jnp.zeros((8, 16), jnp.int32),
    "labels": jnp.zeros((8, 16), jnp.int32),
}
with mesh4:
    p2, o2, m = step(restored, ostate, batch)
assert np.isfinite(float(m["loss"]))
print("ELASTIC_OK", float(m["loss"]))
"""


@pytest.mark.slow
def test_elastic_checkpoint_restart():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        cwd=Path(__file__).resolve().parents[1],
        capture_output=True, text=True, timeout=1200,
    )
    assert "ELASTIC_OK" in proc.stdout, (
        proc.stdout[-2000:], proc.stderr[-4000:]
    )
