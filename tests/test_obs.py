"""Tests for repro.obs (PR 7): metrics semantics, span parenting,
exporters, the trace-context sidecar on the wire codec, and the
end-to-end acceptance run — one traced ``insert_batch`` over
``transport="process"`` at S=2 must produce a Chrome trace whose
shard-side spans carry the coordinator's trace id across the
socketpair, parented under the per-shard wire spans."""

import json
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.api import ClusterConfig, build_index
from repro.data import blobs
from repro.obs import (
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
    NULL_OBS,
    Histogram,
    MetricsRegistry,
    Obs,
    Span,
    Tracer,
    histogram_summary,
    load_chrome,
    make_obs,
    merge_snapshots,
    snapshot_json,
    span_stats,
    to_chrome,
    to_prometheus,
    write_chrome,
)
from repro.obs.cli import main as obs_main
from repro.service.messages import StatsReq
from repro.service.codec import decode, encode


def cfg_for(shards, transport="local", obs=False, **kw):
    base = dict(d=4, k=6, t=6, eps=0.45, seed=0, backend="sharded",
                inner_backend="dynamic")
    base.update(kw)
    return ClusterConfig(shards=shards, transport=transport, obs=obs, **base)


# ---------------------------------------------------------------------- #
# metrics
# ---------------------------------------------------------------------- #
class TestMetrics:
    def test_counter_gauge_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("ops")
        c.inc()
        c.inc(4)
        g = reg.gauge("depth")
        g.set(3)
        g.set(7.5)
        snap = reg.snapshot()
        assert snap["ops"] == {"type": "counter", "value": 5}
        assert snap["depth"] == {"type": "gauge", "value": 7.5}

    def test_registry_is_idempotent_and_kind_checked(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(TypeError):
            reg.histogram("x")  # same name, different kind

    def test_histogram_exact_moments_and_bucketed_percentiles(self):
        h = Histogram("lat_us")
        for v in (3.0, 5.0, 100.0, 900.0):
            h.observe(v)
        s = h.snapshot()
        assert s["count"] == 4 and s["sum"] == pytest.approx(1008.0)
        assert s["min"] == 3.0 and s["max"] == 900.0
        # log2 buckets: percentiles are bucket midpoints clamped to the
        # exact [min, max] envelope
        assert s["min"] <= s["p50"] <= s["p99"] <= s["max"]
        # the bucket upper bounds are powers of two covering max
        bounds = [float(b) for b in s["buckets"]]
        assert all(b == 2.0 ** round(np.log2(b)) for b in bounds)
        assert max(bounds) >= 900.0 and sum(s["buckets"].values()) == 4

    def test_histogram_percentile_edge_cases(self):
        h = Histogram("empty")
        assert h.percentile(50) == 0.0
        h.observe(0.0)  # non-positive values land in the smallest bucket
        assert h.snapshot()["count"] == 1

    def test_timer_records_elapsed_microseconds(self):
        h = Histogram("t_us")
        with h.timer():
            time.sleep(0.01)
        s = h.snapshot()
        assert s["count"] == 1
        assert 5_000 <= s["sum"] <= 2_000_000  # 10ms sleep, generous ceiling

    def test_null_instruments_are_inert_singletons(self):
        obs = make_obs(False)
        assert obs is NULL_OBS and not obs.enabled
        assert obs.counter("a") is NULL_COUNTER
        assert obs.gauge("b") is NULL_GAUGE
        assert obs.histogram("c") is NULL_HISTOGRAM
        obs.counter("a").inc(10)
        obs.gauge("b").set(1)
        obs.histogram("c").observe(5)
        with obs.histogram("c").timer():
            pass
        with obs.tracer.span("nope"):
            assert obs.tracer.context() is None
        snap = obs.snapshot()
        assert snap["metrics"] == {} and snap["spans"] == []
        assert obs.drain() == snap

    def test_make_obs_enabled_returns_live_handle(self):
        obs = make_obs(True, proc="worker3")
        assert obs.enabled and obs.proc == "worker3"
        obs.counter("n").inc()
        assert obs.snapshot()["metrics"]["n"]["value"] == 1


# ---------------------------------------------------------------------- #
# tracing
# ---------------------------------------------------------------------- #
class TestTrace:
    def test_nested_spans_share_trace_and_link_parent(self):
        tr = Tracer(proc="main")
        with tr.span("outer") as a:
            with tr.span("inner") as b:
                assert b.trace_id == a.trace_id
                assert b.parent_id == a.span_id
        out = tr.drain_export()
        by_name = {s["name"]: s for s in out}
        assert by_name["inner"]["parent"] == by_name["outer"]["span"]
        assert by_name["outer"]["parent"] is None
        assert by_name["inner"]["dur"] >= 0

    def test_sibling_spans_get_distinct_ids(self):
        tr = Tracer()
        with tr.span("root"):
            with tr.span("a"):
                pass
            with tr.span("b"):
                pass
        spans = {s["name"]: s for s in tr.drain_export()}
        assert spans["a"]["span"] != spans["b"]["span"]
        assert spans["a"]["parent"] == spans["b"]["parent"]

    def test_adopt_parents_under_a_remote_context(self):
        # the server side of the wire: adopt() installs the coordinator's
        # (trace, span) so the first local span parents across processes
        coord = Tracer(proc="coordinator")
        with coord.span("coord.op") as root:
            ctx = root.wire_ctx()
        shard = Tracer(proc="shard0")
        with shard.adopt(ctx):
            with shard.span("shard.op"):
                pass
        (sp,) = shard.drain_export()
        assert sp["trace"] == ctx["t"] and sp["parent"] == ctx["s"]
        # adoption is scoped: after the block the ambient parent is gone
        assert shard.context() is None

    def test_ingest_round_trips_remote_summaries(self):
        remote = Tracer(proc="shard1")
        with remote.span("shard.insert_batch", n=7):
            pass
        summaries = remote.drain_export()
        assert remote.export() == []  # drain empties the buffer
        local = Tracer(proc="coordinator")
        local.ingest(summaries)
        (sp,) = local.export()
        assert sp["name"] == "shard.insert_batch" and sp["proc"] == "shard1"
        assert sp["args"]["n"] == 7

    def test_span_export_round_trip(self):
        tr = Tracer()
        with tr.span("x", k=1) as sp:
            pass
        d = sp.export()
        back = Span.from_export(d)
        assert back.export() == d

    def test_buffer_capacity_counts_drops(self):
        tr = Tracer(capacity=2)
        for i in range(5):
            with tr.span(f"s{i}"):
                pass
        assert len(tr.export()) == 2 and tr.dropped == 3


# ---------------------------------------------------------------------- #
# exporters
# ---------------------------------------------------------------------- #
def _sample_obs():
    obs = Obs(proc="coordinator")
    obs.counter("bridge.rep_cache_hit").inc(3)
    obs.gauge("serving.queue_depth").set(2)
    h = obs.histogram("coord.insert_batch_us")
    for v in (10.0, 40.0, 300.0):
        h.observe(v)
    with obs.tracer.span("coord.insert_batch", n=3):
        with obs.tracer.span("bridge.merge"):
            pass
    return obs


class TestExport:
    def test_merge_snapshots_prefixes_proc(self):
        a, b = Obs(proc="coordinator"), Obs(proc="shard0")
        a.counter("n").inc()
        b.counter("n").inc(2)
        merged = merge_snapshots([a.snapshot(), b.snapshot()])
        assert merged["metrics"]["coordinator/n"]["value"] == 1
        assert merged["metrics"]["shard0/n"]["value"] == 2
        assert merged["spans"] == [] and merged["spans_dropped"] == 0

    def test_prometheus_exposition(self):
        merged = merge_snapshots([_sample_obs().snapshot()])
        text = to_prometheus(merged["metrics"])
        assert "repro_coordinator_bridge_rep_cache_hit_total 3" in text
        assert "repro_coordinator_serving_queue_depth 2" in text
        # histogram: cumulative buckets ending at +Inf, plus sum/count
        assert 'le="+Inf"} 3' in text
        assert "repro_coordinator_coord_insert_batch_us_count 3" in text
        lines = [ln for ln in text.splitlines() if "_bucket" in ln]
        counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
        assert counts == sorted(counts)  # cumulative, monotone

    def test_chrome_trace_structure_and_roundtrip(self, tmp_path):
        obs = _sample_obs()
        doc = to_chrome(obs.snapshot()["spans"])
        kinds = {e["ph"] for e in doc["traceEvents"]}
        assert kinds == {"M", "X"}
        meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
        assert meta[0]["name"] == "process_name"
        assert meta[0]["args"]["name"] == "coordinator"
        path = tmp_path / "trace.json"
        write_chrome(path, obs.snapshot()["spans"])
        events = load_chrome(path)
        assert {e["name"] for e in events} == {"coord.insert_batch",
                                              "bridge.merge"}
        by_name = {e["name"]: e for e in events}
        assert (by_name["bridge.merge"]["args"]["parent"]
                == by_name["coord.insert_batch"]["args"]["span"])

    def test_span_stats_and_histogram_summary(self):
        obs = _sample_obs()
        events = to_chrome(obs.snapshot()["spans"])["traceEvents"]
        rows = span_stats([e for e in events if e["ph"] == "X"])
        assert [r["op"] for r in rows][0] in ("coord.insert_batch",
                                              "bridge.merge")
        assert all(r["count"] == 1 and r["p50_us"] <= r["p99_us"]
                   for r in rows)
        summ = histogram_summary(merge_snapshots([obs.snapshot()])["metrics"])
        row = summ["coordinator/coord.insert_batch_us"]
        assert row["count"] == 3 and row["mean"] == pytest.approx(350.0 / 3)

    def test_snapshot_json_is_json(self):
        doc = json.loads(snapshot_json(_sample_obs().snapshot()))
        assert doc["proc"] == "coordinator"


# ---------------------------------------------------------------------- #
# wire sidecar: absent context is bit-identical, present context ships
# ---------------------------------------------------------------------- #
class TestWireSidecar:
    def test_untraced_encode_is_bit_identical(self):
        ref = encode(StatsReq(want_obs=False))
        msg = StatsReq(want_obs=False)
        assert msg.trace_ctx is None and msg.span_summary is None
        assert encode(msg) == ref
        assert b"__trace__" not in ref and b"__spans__" not in ref

    def test_trace_context_round_trips_and_changes_bytes(self):
        plain = encode(StatsReq())
        msg = StatsReq()
        msg.trace_ctx = {"t": 12345, "s": 678}
        traced = encode(msg)
        assert traced != plain
        back = decode(traced)
        assert back.trace_ctx == {"t": 12345, "s": 678}
        # the sidecar is per-message state, not class state
        assert StatsReq().trace_ctx is None

    def test_span_summary_round_trips(self):
        tr = Tracer(proc="shard0")
        with tr.span("shard.labels"):
            pass
        msg = StatsReq()
        msg.span_summary = tr.drain_export()
        back = decode(encode(msg))
        assert back.span_summary is not None
        (sp,) = back.span_summary
        assert sp["name"] == "shard.labels" and sp["proc"] == "shard0"


# ---------------------------------------------------------------------- #
# end to end: the acceptance run
# ---------------------------------------------------------------------- #
class TestEndToEnd:
    def test_disabled_obs_is_the_null_object(self):
        ix = build_index(cfg_for(2, "local", obs=False))
        try:
            assert ix.obs is NULL_OBS
            ix.insert_batch(np.zeros((4, 4)))
            assert ix.obs_snapshot() == []
        finally:
            ix.close()

    def test_local_transport_traces_and_metrics(self):
        X, _ = blobs(n=80, d=4, n_clusters=2, cluster_std=0.2, seed=2)
        ix = build_index(cfg_for(2, "local", obs=True, seed=2))
        try:
            ids = ix.insert_batch(X)
            ix.labels()
            ix.delete_batch(ids[:10])
            ix.obs_refresh()
            merged = merge_snapshots(ix.obs_snapshot())
            names = set(merged["metrics"])
            assert "coordinator/coord.insert_batch_us" in names
            assert "coordinator/rpc.shard0_us" in names
            assert "coordinator/rpc.shard1_us" in names
            assert "coordinator/bridge.epoch" in names
            assert "coordinator/router.load_skew" in names
            # shard-side registries ride back through StatsReq(want_obs)
            assert any(n.startswith("shard0/") for n in names)
            span_names = {s["name"] for s in merged["spans"]}
            assert {"coord.insert_batch", "bridge.insert",
                    "bridge.merge", "shard.insert_batch"} <= span_names
        finally:
            ix.close()

    def test_process_transport_spans_cross_the_socketpair(self, tmp_path):
        """Acceptance criterion: a traced insert_batch at S=2 over the
        process transport renders coordinator -> wire -> shard spans with
        correct parentage and one shared trace id per coordinator op."""
        X, _ = blobs(n=60, d=4, n_clusters=2, cluster_std=0.2, seed=4)
        ix = build_index(cfg_for(2, "process", obs=True, seed=4))
        try:
            ix.insert_batch(X)
            ix.labels()
            path = ix.write_trace(tmp_path / "trace.json")
        finally:
            ix.close()
        doc = json.loads(path.read_text())
        lane = {e["pid"]: e["args"]["name"]
                for e in doc["traceEvents"] if e["ph"] == "M"}
        events = load_chrome(path)
        procs = {lane[e["pid"]] for e in events}
        assert {"coordinator", "shard0", "shard1"} <= procs
        by_span = {e["args"]["span"]: e for e in events}
        wire = [e for e in events if e["name"].startswith("wire.shard")]
        assert {e["name"] for e in wire} >= {"wire.shard0", "wire.shard1"}
        # startup hellos and obs pulls trace as their own roots; the op
        # spans issued inside coordinator ops are the parentage criterion
        shard_spans = [e for e in events
                       if lane[e["pid"]].startswith("shard")
                       and e["name"] in ("shard.insert_batch",
                                         "shard.labels")]
        assert shard_spans, "no server-side spans shipped back"
        for e in shard_spans:
            parent = by_span.get(e["args"]["parent"])
            assert parent is not None, e["name"]
            assert parent["name"].startswith("wire.shard")
            # one trace id flows coordinator -> wire -> shard
            assert e["args"]["trace"] == parent["args"]["trace"]
            root = by_span[parent["args"]["parent"]]
            assert lane[root["pid"]] == "coordinator"
            assert root["args"]["trace"] == e["args"]["trace"]

    def test_cli_report_renders_per_op_latency(self, tmp_path, capsys):
        ix = build_index(cfg_for(2, "local", obs=True))
        try:
            ix.insert_batch(np.random.default_rng(0).normal(size=(40, 4)))
            ix.labels()
            path = ix.write_trace(tmp_path / "t.json")
        finally:
            ix.close()
        assert obs_main(["report", str(path)]) == 0
        out = capsys.readouterr().out
        assert "p50" in out and "p99" in out
        assert "coord.insert_batch" in out
        # --json mode emits machine-readable rows
        assert obs_main(["report", str(path), "--json"]) == 0
        rows = json.loads(capsys.readouterr().out)
        assert any(r["op"] == "coord.insert_batch" for r in rows)

    def test_cli_module_entrypoint(self, tmp_path):
        obs = _sample_obs()
        path = tmp_path / "trace.json"
        write_chrome(path, obs.snapshot()["spans"])
        out = subprocess.run(
            [sys.executable, "-m", "repro.obs", "report", str(path)],
            capture_output=True, text=True)
        assert out.returncode == 0, out.stderr
        assert "coord.insert_batch" in out.stdout

    def test_cli_prom_subcommand(self, tmp_path, capsys):
        path = tmp_path / "snap.json"
        path.write_text(snapshot_json(_sample_obs().snapshot()))
        assert obs_main(["prom", str(path)]) == 0
        out = capsys.readouterr().out
        assert "repro_coordinator_bridge_rep_cache_hit_total 3" in out

    def test_bridge_cache_counters_move(self):
        X, _ = blobs(n=120, d=4, n_clusters=3, cluster_std=0.2, seed=7)
        ix = build_index(cfg_for(2, "local", obs=True, seed=7))
        try:
            ids = ix.insert_batch(X)
            # point queries drive bridge.resolve: the first after a
            # mutation rebuilds the quotient (miss), repeats hit the
            # epoch-stamped cache
            ix.label(ids[0])
            ix.label(ids[1])
            ix.label(ids[2])
            m = ix.obs.snapshot()["metrics"]
            assert m["bridge.quotient_cache_miss"]["value"] >= 1
            assert m["bridge.quotient_cache_hit"]["value"] >= 1
            assert m["coord.label_us"]["count"] == 3
        finally:
            ix.close()
