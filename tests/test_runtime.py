"""Fault-tolerance runtime: heartbeats, stragglers, elastic planning,
checkpoint save/restore (+async, atomic, reshard), gradient compression."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.distributed import (
    int8_compress_decompress,
    make_compressed_grad_transform,
    topk_compress_decompress,
)
from repro.runtime import HeartbeatRegistry, StragglerDetector, plan_remesh


# --------------------------------------------------------------------- #
# heartbeat
# --------------------------------------------------------------------- #
def test_heartbeat_failure_detection():
    t = [0.0]
    hb = HeartbeatRegistry(4, timeout_s=10.0, clock=lambda: t[0])
    for h in range(4):
        hb.beat(h, step=5)
    t[0] = 8.0
    hb.beat(0, step=6)
    hb.beat(1, step=6)
    assert hb.failed() == []
    t[0] = 12.0
    assert hb.failed() == [2, 3]
    assert hb.alive() == [0, 1]
    hb.evict(2)
    hb.evict(3)
    assert hb.quorum_step() == 6
    hb.rejoin(2)
    assert 2 in hb.alive()


def test_straggler_detection():
    sd = StragglerDetector(4, threshold=1.5, patience=2)
    for step in range(6):
        for h in range(4):
            sd.record(h, 1.0 if h != 3 else 3.0)
        sd.update_breaches()
    assert sd.stragglers() == [3]
    # recovery clears the flag once the EWMA decays under threshold
    for step in range(15):
        for h in range(4):
            sd.record(h, 1.0)
        sd.update_breaches()
    assert sd.stragglers() == []


def test_straggler_fed_from_obs_histograms():
    """The serving-side signal: per-shard RPC latency histograms from an
    Obs snapshot stand in for synthetic step-time probes."""
    from repro.obs import Obs

    obs = Obs(proc="coordinator")
    sd = StragglerDetector(3, threshold=1.5, patience=2)
    for round_ in range(6):
        for s, lat_us in enumerate((1000.0, 1100.0, 9000.0)):
            obs.histogram(f"rpc.shard{s}_us").observe(lat_us)
        fed = sd.record_from_obs(obs.snapshot()["metrics"])
        assert fed == [0, 1, 2]
    assert sd.stragglers() == [2]
    # p50 microseconds scale to seconds
    assert 0.0005 < sd.ewma(0) < 0.005
    # a snapshot with no matching histograms feeds nothing and leaves
    # breach counters untouched
    assert sd.record_from_obs({"unrelated": {"type": "counter",
                                             "value": 3}}) == []
    assert sd.stragglers() == [2]


# --------------------------------------------------------------------- #
# elastic planning
# --------------------------------------------------------------------- #
def test_plan_remesh_drops_to_pow2_dp():
    # 7 surviving hosts × 4 chips, model=4 → 28 chips → dp=4 (pow2 ≤ 7)
    plan = plan_remesh(list(range(7)), chips_per_host=4, model_parallel=4,
                       global_batch=256, microbatch=16)
    assert plan.data_parallel == 4
    assert plan.grad_accum == 4  # 4 × 16 × 4 == 256
    assert len(plan.hosts) == 4
    assert set(plan.dropped_hosts) == {4, 5, 6}


def test_plan_remesh_infeasible():
    assert plan_remesh([0], chips_per_host=4, model_parallel=16,
                       global_batch=64, microbatch=8) is None


def test_plan_remesh_preserves_global_batch():
    for n_hosts in (2, 3, 5, 8, 13):
        plan = plan_remesh(list(range(n_hosts)), 8, 8, 512, 8)
        if plan is None:
            continue
        assert plan.grad_accum * plan.data_parallel * 8 >= 512


# --------------------------------------------------------------------- #
# checkpointing
# --------------------------------------------------------------------- #
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)},
        "head": jnp.asarray(rng.normal(size=(8,)), jnp.float32),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_write=False)
    tree = _tree()
    mgr.save(100, tree, extra={"loss": 1.5})
    assert mgr.latest_step() == 100
    restored = mgr.restore(jax.tree.map(lambda x: jnp.zeros_like(x), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.manifest()["extra"]["loss"] == 1.5


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2, async_write=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    mgr.wait()
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4
    r = mgr.restore(_tree(), step=4)
    np.testing.assert_array_equal(
        np.asarray(r["head"]), np.asarray(_tree(4)["head"])
    )


def test_checkpoint_crash_safety(tmp_path):
    """A stale temp dir must not corrupt LATEST."""
    mgr = CheckpointManager(tmp_path, async_write=False)
    mgr.save(10, _tree())
    (tmp_path / ".tmp_step_00000020_999").mkdir()
    assert mgr.latest_step() == 10
    mgr.restore(_tree(), step=10)


# --------------------------------------------------------------------- #
# gradient compression
# --------------------------------------------------------------------- #
def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    deq, res = int8_compress_decompress(g)
    assert float(jnp.abs(res).max()) <= float(jnp.abs(g).max()) / 127 + 1e-6
    np.testing.assert_allclose(np.asarray(deq + res), np.asarray(g), atol=1e-6)


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05], jnp.float32)
    kept, res = topk_compress_decompress(g, frac=0.4)
    assert float(kept[1]) == -5.0 and float(kept[3]) == 3.0
    assert float(kept[0]) == 0.0
    np.testing.assert_allclose(np.asarray(kept + res), np.asarray(g), atol=1e-6)


def test_error_feedback_converges():
    """With error feedback, the *sum* of compressed grads tracks the sum of
    true grads (bias-free compression)."""
    init, transform = make_compressed_grad_transform("int8")
    params = {"w": jnp.zeros((64,))}
    res = init(params)
    rng = np.random.default_rng(1)
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        comp, res = transform(g, res)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(comp["w"])
    # residual bounds the gap
    gap = np.abs(total_true - total_comp).max()
    assert gap <= float(jnp.abs(res["w"]).max()) + 1e-5
