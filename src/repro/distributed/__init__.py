from .compression import int8_compress_decompress, make_compressed_grad_transform, topk_compress_decompress  # noqa: F401
