from .compression import (  # noqa: F401
    int8_compress_decompress,
    make_compressed_grad_transform,
    topk_compress_decompress,
)
