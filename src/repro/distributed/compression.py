"""Gradient compression for the DP reduction (distributed-optimization).

Two schemes, both with error feedback (the residual is carried in f32 and
added back next step, so compression error doesn't accumulate as bias):

  * int8: per-tensor-block symmetric quantisation (scale = max|g|/127).
    8 GB of f32 gradient traffic becomes ~2 GB on the wire.
  * top-k: keep the k largest-|g| entries per tensor (values + indices).

Under pjit the DP reduction is implicit in the backward pass, so the hook
applies compress→decompress to the *accumulated* gradient before the
optimizer: on a real fleet the compressed representation is what crosses
DCN between pods (the pod-axis all-reduce); the simulation faithfully
reproduces the numerics (quantise → sum → dequantise ≡ the wire path for
layer-wise symmetric scales).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def int8_compress_decompress(g: jnp.ndarray, block: int = 4096):
    """Quantise to int8 per block, return (dequantised, residual)."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % block
    fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.shape[0]]
    deq = deq.reshape(g.shape)
    return deq, g.astype(jnp.float32) - deq


def topk_compress_decompress(g: jnp.ndarray, frac: float = 0.05):
    """Keep the top-|frac| entries; everything else becomes residual."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return kept.reshape(g.shape), (flat - kept).reshape(g.shape)


def make_compressed_grad_transform(
    scheme: str = "int8", frac: float = 0.05,
) -> Tuple[Callable, Callable]:
    """Returns (init_residuals, transform(grads, residuals) ->
    (compressed_grads, new_residuals)) with error feedback."""

    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    def transform(grads, residuals):
        def one(g, r):
            gg = g.astype(jnp.float32) + r
            if scheme == "int8":
                out, res = int8_compress_decompress(gg)
            elif scheme == "topk":
                out, res = topk_compress_decompress(gg, frac)
            else:
                raise ValueError(scheme)
            return out, res

        flat_g, tdef = jax.tree.flatten(grads)
        flat_r = tdef.flatten_up_to(residuals)
        outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
        return (
            tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]),
        )

    return init, transform
