"""Batched serving engine: prefill + decode over a shared KV cache, with
optional dynamic-DBSCAN request clustering.

Continuous-batching-style loop for a fixed batch width B:
  * incoming requests queue up; free slots are filled by prefilling the
    request's prompt into the slot's cache region;
  * one fused decode step advances every active slot by a token;
  * finished slots (EOS / max_len) are released.

Request clustering (the paper's technique on the serving side): request
embeddings are clustered online; the scheduler can batch same-cluster
requests together (prefix/topic locality) and expire old requests from the
window — again the paper's insert+delete workload.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..api import ClusterConfig, build_index
from ..models.registry import ModelAPI
from ..obs import NULL_OBS, Obs


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (len,) int32
    max_new_tokens: int = 16
    embedding: Optional[np.ndarray] = None
    out_tokens: Optional[List[int]] = None
    cluster: Optional[int] = None
    # engine-managed state, declared so snapshots/introspection and type
    # checkers see the full shape of an in-flight request
    _cidx: Optional[int] = None   # clusterer handle of this request's embedding
    _next: Optional[int] = None   # next token to feed the fused decode step


class ServingEngine:
    def __init__(self, model: ModelAPI, params, batch: int, kv_len: int,
                 eos_id: int = -1, cluster_requests: bool = False,
                 embed_dim: int = 8, mesh=None,
                 cluster_backend: str = "batched",
                 cluster_shards: int = 1,
                 cluster_workers: int = 0,
                 cluster_transport: str = "local",
                 cluster_replicas: int = 0,
                 cluster_tier: Optional[float] = None,
                 obs: Obs = NULL_OBS):
        self.model = model
        # serving telemetry: per-op latency + scheduler state gauges.
        # Passing a live Obs also turns the clusterer's own obs knob on,
        # so one handle observes the full request path.
        self.obs = obs
        self._h_submit_us = obs.histogram("serving.submit_us")
        self._h_step_us = obs.histogram("serving.step_us")
        self._g_queue = obs.gauge("serving.queue_depth")
        self._g_active = obs.gauge("serving.active_slots")
        self.params = params
        self.B = batch
        self.kv_len = kv_len
        self.eos = eos_id
        self.mesh = mesh
        self.caches, _ = model.decode_init(batch, kv_len)
        self._step = jax.jit(
            lambda p, c, t, pos, act: model.decode_step(
                p, c, t, pos, mesh, active=act
            )
        )
        self.slots: List[Optional[Request]] = [None] * batch
        self.slot_pos = np.zeros(batch, dtype=np.int64)
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        # cluster_shards > 1 shards the request-clustering window by LSH
        # key range (cluster_backend becomes the per-shard inner engine);
        # cluster_workers > 1 fans the per-shard sub-batches out on a
        # thread pool, and cluster_transport="process"/"tcp" runs each
        # shard as its own server process (GIL-free updates).
        # cluster_replicas > 0 backs every shard with that many replicas:
        # a shard worker dying mid-serve fails over instead of failing
        # requests.  label() on the sharded backend is an incremental
        # point query, so per-request labelling stays off the O(n) path.
        # cluster_tier=<rate> switches to tiered serving (repro.tiered):
        # a sampled-core front tier at that sample_rate labels requests
        # immediately while the exact tier verifies asynchronously —
        # divergence shows up on this engine's obs as tiered.* gauges.
        if cluster_tier is not None:
            cluster_backend = "tiered"
        self.clusterer = (
            build_index(ClusterConfig(d=embed_dim, k=4, t=6, eps=0.6,
                                      backend=cluster_backend,
                                      workers=cluster_workers,
                                      transport=cluster_transport,
                                      replicas=cluster_replicas,
                                      sample_rate=(cluster_tier
                                                   if cluster_tier is not None
                                                   else 1.0),
                                      obs=obs.enabled)
                        .with_shards(cluster_shards))
            if cluster_requests else None
        )
        # sliding admission window: evicted at the head on every submit
        # past capacity — deque keeps that O(1) at high request rates
        self._req_window: Deque[int] = collections.deque()

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        with self.obs.tracer.span("serving.submit", rid=req.rid), \
                self._h_submit_us.timer():
            self._submit_impl(req)
        self._g_queue.set(len(self.queue))

    def _submit_impl(self, req: Request) -> None:
        req.out_tokens = []
        if self.clusterer is not None and req.embedding is not None:
            idx = self.clusterer.insert_batch(req.embedding[None])[0]
            req.cluster = self.clusterer.label(idx)
            req._cidx = idx
            self._req_window.append(idx)
            if len(self._req_window) > 4 * self.B:
                self.clusterer.delete(self._req_window.popleft())
            # change feed as a refresh trigger: attachment deltas
            # under-report merges (a bridging core — or a cross-shard
            # union — changes handles of points it never touched), so a
            # non-empty feed re-labels the requests scheduling actually
            # reads: the queue and the active slots.  label() is the
            # incremental hot-path query, so this stays O(queue), not
            # O(window).
            if self.clusterer.drain_deltas() != []:
                for r in (*self.queue, *filter(None, self.slots)):
                    i = r._cidx
                    if i is not None and i in self.clusterer:
                        r.cluster = self.clusterer.label(i)
        self.queue.append(req)

    def _schedule(self) -> None:
        """Fill free slots; prefer same-cluster requests (locality)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.queue:
            return
        if self.clusterer is not None:
            active = [s.cluster for s in self.slots if s is not None]
            self.queue.sort(
                key=lambda r: (r.cluster not in active, r.rid)
            )
        for i in free:
            if not self.queue:
                break
            req = self.queue.pop(0)
            self._prefill(i, req)

    def _prefill(self, slot: int, req: Request) -> None:
        """Teacher-force the prompt through the decode path one token at a
        time (simple, exact; a production engine would run a fused prefill
        kernel into the cache region)."""
        self.slots[slot] = req
        self.slot_pos[slot] = 0
        for t, tok in enumerate(req.prompt[:-1]):
            self._advance_slot(slot, int(tok))
        req._next = int(req.prompt[-1])

    def _advance_slot(self, slot: int, token: int) -> None:
        tokens = np.zeros((self.B, 1), dtype=np.int32)
        tokens[slot, 0] = token
        mask = np.zeros((self.B,), dtype=bool)
        mask[slot] = True
        _, self.caches = self._step(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos.astype(np.int32)), jnp.asarray(mask),
        )
        self.slot_pos[slot] += 1

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One fused decode step for all active slots; returns #active."""
        with self._h_step_us.timer():
            n = self._step_impl()
        self._g_queue.set(len(self.queue))
        self._g_active.set(n)
        return n

    def _step_impl(self) -> int:
        self._schedule()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), dtype=np.int32)
        mask = np.zeros((self.B,), dtype=bool)
        for i in active:
            tokens[i, 0] = self.slots[i]._next
            mask[i] = True
        logits, self.caches = self._step(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos.astype(np.int32)), jnp.asarray(mask),
        )
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.out_tokens.append(tok)
            req._next = tok
            self.slot_pos[i] += 1
            if (tok == self.eos or len(req.out_tokens) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.kv_len - 1):
                self.done[req.rid] = req
                self.slots[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, Request]:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.done

    def close(self) -> None:
        """Release the clusterer's external resources (shard worker
        processes under ``cluster_transport="process"``)."""
        if self.clusterer is not None:
            self.clusterer.close()
