"""GQA attention block: chunked (flash-style) jnp path + decode path.

The jnp chunked path is the portable implementation the dry-run lowers
(online softmax over q-chunks, O(chunk · kv) live memory); on TPU hardware
the Pallas kernel (`repro.kernels.flash_attention`) slots in via
``impl='pallas'``.  Decode attends one token against a (possibly
sequence-sharded) KV cache; softmax/contraction over the sharded axis
lowers to small all-reduces under GSPMD (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard_activation
from . import layers as L

NEG_INF = -1e30


def init_attention(key, cfg, dtype=jnp.float32):
    E = cfg.d_model
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    std = L.fan_in_std(E)
    decls = {
        "wq": ((E, Hq, Dh), ("embed", "heads", "head_dim"), std),
        "wk": ((E, Hkv, Dh), ("embed", "kv_heads", "head_dim"), std),
        "wv": ((E, Hkv, Dh), ("embed", "kv_heads", "head_dim"), std),
        "wo": ((Hq, Dh, E), ("heads", "head_dim", "embed"), L.fan_in_std(Hq * Dh)),
    }
    if cfg.qkv_bias:
        decls.update({
            "bq": ((Hq, Dh), ("heads", "head_dim"), 0.0),
            "bk": ((Hkv, Dh), ("kv_heads", "head_dim"), 0.0),
            "bv": ((Hkv, Dh), ("kv_heads", "head_dim"), 0.0),
        })
    return L.declare(key, decls, dtype)


def _project_qkv(p, x, cfg, compute_dtype):
    q = jnp.einsum("bse,ehd->bhsd", x, p["wq"].astype(compute_dtype))
    k = jnp.einsum("bse,ehd->bhsd", x, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bse,ehd->bhsd", x, p["wv"].astype(compute_dtype))
    if "bq" in p:
        q = q + p["bq"].astype(compute_dtype)[None, :, None, :]
        k = k + p["bk"].astype(compute_dtype)[None, :, None, :]
        v = v + p["bv"].astype(compute_dtype)[None, :, None, :]
    return q, k, v


def chunked_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    *, causal: bool = True, window=None, chunk: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """q: (b, hq, sq, dh); k, v: (b, hkv, skv, dh).  ``window`` may be a
    traced scalar (per-layer metadata inside scans); <= 0 means full."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    chunk = min(chunk, sq)
    pad = -sq % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nq = (sq + pad) // chunk
    qc = q.reshape(b, hkv, g, nq, chunk, dh).transpose(3, 0, 1, 2, 4, 5)
    k_pos = jnp.arange(skv)

    win = jnp.asarray(-1 if window is None else window, jnp.int32)

    def one_chunk(ci, qi):
        # qi: (b, hkv, g, chunk, dh)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        q_pos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, skv), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        mask &= jnp.where(
            win > 0, (q_pos[:, None] - k_pos[None, :]) < win, True
        )
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v)

    out = jax.lax.map(lambda args: one_chunk(*args),
                      (jnp.arange(nq), qc))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq + pad, dh)
    return out[:, :, :sq]


def attention_block(
    p: Dict[str, Any], x: jnp.ndarray, cfg, *,
    theta, window, compute_dtype, positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
) -> jnp.ndarray:
    """Full-sequence (train/prefill) attention block."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, compute_dtype)
    q = shard_activation(q, ("batch", "heads", None, None))
    k = shard_activation(k, ("batch", "kv_heads", None, None))
    v = shard_activation(v, ("batch", "kv_heads", None, None))
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if theta is not None:
        q = L.rope(q, positions[:, None, :], theta)
        k = L.rope(k, positions[:, None, :], theta)
    out = chunked_attention(
        q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk
    )
    out = shard_activation(out, ("batch", "heads", None, None))
    return jnp.einsum("bhsd,hde->bse", out, p["wo"].astype(compute_dtype))


# --------------------------------------------------------------------- #
# decode path
# --------------------------------------------------------------------- #
def init_kv_cache(cfg, batch: int, kv_len: int, n_layers: int, dtype):
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, Hkv, kv_len, Dh)
    axes = ("layers", "cache_batch", "kv_heads", "cache_seq", "head_dim")
    return (
        {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
        {"k": axes, "v": axes},
    )


def decode_attention_block(
    p: Dict[str, Any], x: jnp.ndarray, cache_k, cache_v, pos, cfg, *,
    theta, window, compute_dtype, windowed_cache: bool = False,
    active: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode.  x: (b, 1, E); cache_k/v: (b, hkv, S, dh).

    ``pos``: scalar int32 or per-row (b,) int32 — absolute position of each
    row's new token (continuous batching).  ``active``: optional (b,) bool;
    inactive rows leave their cache untouched.

    Full cache: written at slot pos[i] per row.  Windowed cache (gemma3
    local layers): shift-left ring of size W — requires a uniform scalar
    ``pos`` (batch-synchronous decode).
    """
    b = x.shape[0]
    S = cache_k.shape[2]
    q, k, v = _project_qkv(p, x, cfg, compute_dtype)  # (b, h, 1, dh)
    pos_vec = jnp.broadcast_to(jnp.atleast_1d(pos), (b,)).astype(jnp.int32)
    posv = pos_vec[:, None, None]
    if theta is not None:
        q = L.rope(q, posv, theta)
        k = L.rope(k, posv, theta)
    if active is None:
        act = jnp.ones((b,), bool)
    else:
        act = active

    if windowed_cache:
        new_k = jnp.roll(cache_k, -1, axis=2)
        new_v = jnp.roll(cache_v, -1, axis=2)
        new_k = jax.lax.dynamic_update_slice(new_k, k, (0, 0, S - 1, 0))
        new_v = jax.lax.dynamic_update_slice(new_v, v, (0, 0, S - 1, 0))
        # slot j holds absolute position pos - (S-1-j)
        k_pos = pos_vec[:, None] - (S - 1 - jnp.arange(S))[None, :]
        valid = k_pos >= 0
    elif jnp.ndim(pos) == 0:
        # batch-synchronous decode (the dry-run/serve_step fast path):
        # dynamic_update_slice on the seq-sharded cache lowers to a masked
        # local update under GSPMD — a per-row scatter would all-gather
        # the whole cache (measured: 25 GB/step on qwen decode_32k)
        new_k = jax.lax.dynamic_update_slice(
            cache_k, k, (0, 0, pos.astype(jnp.int32), 0)
        )
        new_v = jax.lax.dynamic_update_slice(
            cache_v, v, (0, 0, pos.astype(jnp.int32), 0)
        )
        k_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (b, S))
        valid = k_pos <= pos_vec[:, None]
        if window is not None:
            valid &= (pos_vec[:, None] - k_pos) < jnp.asarray(window)
    else:
        # continuous batching: per-row positions
        idx = jnp.arange(b)
        new_k = cache_k.at[idx, :, pos_vec, :].set(k[:, :, 0, :])
        new_v = cache_v.at[idx, :, pos_vec, :].set(v[:, :, 0, :])
        k_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (b, S))
        valid = k_pos <= pos_vec[:, None]
        if window is not None:
            valid &= (pos_vec[:, None] - k_pos) < jnp.asarray(window)
    sel = act[:, None, None, None]
    cache_k = jnp.where(sel, new_k, cache_k)
    cache_v = jnp.where(sel, new_v, cache_v)

    scale = 1.0 / (cfg.resolved_head_dim ** 0.5)
    hq, hkv = q.shape[1], cache_k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, cache_k.shape[-1])
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", pr.astype(cache_v.dtype), cache_v)
    out = out.reshape(b, 1, hq, cache_k.shape[-1]).transpose(0, 2, 1, 3)
    y = jnp.einsum("bhsd,hde->bse", out, p["wo"].astype(compute_dtype))
    return y, cache_k, cache_v
