"""Decoder-only LM covering the dense / MoE / SSM / hybrid / VLM families.

Training/prefill scans over a stacked layer tree (compile time flat in
depth — required for 80-layer configs lowered at 512 SPMD partitions), with
per-layer metadata arrays (RoPE theta, window) riding the scan so Gemma-3's
5:1 local:global pattern stays a uniform stack.  Decode unrolls a Python
loop over layers so per-layer cache shapes can differ (window-sized caches
for local layers — what makes long-context decode fit HBM).

The cross-entropy never materialises replicated logits: the head output
stays vocab-sharded; logsumexp and the label-pick reduce over the sharded
axis (small all-reduces under GSPMD).
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..sharding import shard_activation
from . import attention as A
from . import layers as L
from . import moe as M
from . import ssm as S


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def _init_layer(key, cfg, dtype):
    fam = cfg.family
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    ks = jax.random.split(key, 8)

    def add(name, sub):
        p, a = sub
        params[name] = p
        axes[name] = a

    if fam in ("dense", "vlm", "moe", "hybrid"):
        add("attn", A.init_attention(ks[0], cfg, dtype))
        add("ln_attn", L.declare(ks[1], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype))
    if fam in ("dense", "vlm"):
        add("mlp", L.init_swiglu(ks[2], cfg.d_model, cfg.d_ff, dtype))
        add("ln_mlp", L.declare(ks[3], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype))
    if fam == "moe":
        add("moe", M.init_moe(ks[2], cfg, dtype))
        add("ln_mlp", L.declare(ks[3], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype))
    if fam in ("ssm", "hybrid"):
        add("ssm", S.init_mamba2(ks[4], cfg, dtype))
        add("ln_ssm", L.declare(ks[5], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype))
    if fam == "hybrid":
        add("mlp", L.init_swiglu(ks[2], cfg.d_model, cfg.d_ff, dtype))
        add("ln_mlp", L.declare(ks[3], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype))
        add("comb", L.declare(ks[6], {
            "norm_attn": ((cfg.d_model,), ("embed_r",), 0.0),
            "norm_ssm": ((cfg.d_model,), ("embed_r",), 0.0),
        }, dtype))
    return params, axes


def layer_metadata(cfg) -> Dict[str, jnp.ndarray]:
    """Per-layer (theta, window) arrays; window -1 = full attention."""
    n = cfg.n_layers
    theta = jnp.full((n,), cfg.rope_theta, jnp.float32)
    window = jnp.full((n,), -1, jnp.int32)
    if cfg.local_global_pattern is not None:
        loc, glob = cfg.local_global_pattern
        period = loc + glob
        is_global = (jnp.arange(n) % period) == (period - 1)
        window = jnp.where(is_global, -1, cfg.window)
        if cfg.rope_theta_global is not None:
            theta = jnp.where(is_global, cfg.rope_theta_global, cfg.rope_theta)
    elif cfg.window is not None:
        window = jnp.full((n,), cfg.window, jnp.int32)
    return {"theta": theta, "window": window}


def init_lm(cfg, key) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    dtype = L.dtype_of(cfg.param_dtype)
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    p, a = L.init_embedding(k_emb, cfg.padded_vocab, cfg.d_model, dtype)
    params["embed"], axes["embed"] = p, a

    lp, la = L.stack_layers(lambda k: _init_layer(k, cfg, dtype), k_layers, cfg.n_layers)
    params["layers"], axes["layers"] = lp, la

    p, a = L.declare(k_head, {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    params["ln_f"], axes["ln_f"] = p, a
    if not cfg.tie_embeddings:
        p, a = L.init_lm_head(k_head, cfg.d_model, cfg.padded_vocab, dtype)
        params["head"], axes["head"] = p, a
    if cfg.family == "vlm":
        p, a = L.declare(k_extra, {
            "w": ((cfg.d_vision, cfg.d_model), (None, "act_mlp"),
                  L.fan_in_std(cfg.d_vision)),
        }, dtype)
        params["vision_proj"], axes["vision_proj"] = p, a
    return params, axes


# --------------------------------------------------------------------- #
# layer forward (shared between scan body and decode loop)
# --------------------------------------------------------------------- #
def _layer_fwd(lp, x, cfg, meta, compute_dtype, mesh):
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam in ("dense", "vlm", "moe"):
        h = L.rms_norm(x, lp["ln_attn"]["w"], cfg.norm_eps)
        x = x + A.attention_block(
            lp["attn"], h, cfg, theta=meta["theta"], window=meta["window"],
            compute_dtype=compute_dtype,
        )
        h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
        if fam == "moe":
            y, aux = M.moe_block(lp["moe"], h, cfg, compute_dtype, mesh)
            x = x + y
        else:
            x = x + L.swiglu(lp["mlp"], h, compute_dtype)
    elif fam == "ssm":
        h = L.rms_norm(x, lp["ln_ssm"]["w"], cfg.norm_eps)
        x = x + S.mamba2_block(lp["ssm"], h, cfg, compute_dtype)
    elif fam == "hybrid":
        h = L.rms_norm(x, lp["ln_attn"]["w"], cfg.norm_eps)
        att = A.attention_block(
            lp["attn"], h, cfg, theta=meta["theta"], window=meta["window"],
            compute_dtype=compute_dtype,
        )
        ssm = S.mamba2_block(lp["ssm"], h, cfg, compute_dtype)
        x = x + 0.5 * (
            L.rms_norm(att, lp["comb"]["norm_attn"], cfg.norm_eps)
            + L.rms_norm(ssm, lp["comb"]["norm_ssm"], cfg.norm_eps)
        )
        h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
        x = x + L.swiglu(lp["mlp"], h, compute_dtype)
    else:
        raise ValueError(fam)
    seq_ax = "act_seq" if cfg.seq_shard_activations else None
    x = shard_activation(x, ("batch", seq_ax, "act_embed"))
    return x, aux


# --------------------------------------------------------------------- #
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------- #
def lm_forward(params, cfg, tokens, mesh=None, patches=None,
               return_hidden: bool = False):
    compute_dtype = L.dtype_of(cfg.dtype)
    x = L.embed(params["embed"], tokens, compute_dtype)
    n_prefix = 0
    if cfg.family == "vlm" and patches is not None:
        vis = jnp.einsum(
            "bpe,ed->bpd", patches.astype(compute_dtype),
            params["vision_proj"]["w"].astype(compute_dtype),
        )
        x = jnp.concatenate([vis, x], axis=1)
        n_prefix = vis.shape[1]
    x = shard_activation(x, ("batch", None, "act_embed"))
    meta = layer_metadata(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, m = xs
        x, a = _layer_fwd(lp, x, cfg, m, compute_dtype, mesh)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), (params["layers"], meta)
    )
    x = L.rms_norm(x, params["ln_f"]["w"], cfg.norm_eps)
    if return_hidden:
        return x, aux, n_prefix
    logits = _head(params, cfg, x, compute_dtype)
    return logits, aux, n_prefix


def _head(params, cfg, x, compute_dtype):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(compute_dtype).T
        logits = jnp.einsum("bse,ev->bsv", x, w)
    else:
        logits = L.lm_head(params["head"], x, compute_dtype)
    return shard_activation(logits, ("batch", None, "act_vocab"))


def lm_loss(params, cfg, batch, mesh=None):
    """Mean next-token CE over valid (label >= 0) positions + MoE aux."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    patches = batch.get("patches")
    logits, aux, n_prefix = lm_forward(params, cfg, tokens, mesh, patches)
    if n_prefix:
        logits = logits[:, n_prefix:]
    ce, denom = _ce(logits, labels, cfg)
    loss = ce / denom + 0.01 * aux
    return loss, {"ce": ce / denom, "aux": aux, "tokens": denom}


def _ce(logits, labels, cfg):
    logits = logits.astype(jnp.float32)
    vp = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    logits = jnp.where(iota < cfg.vocab_size, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.sum(
        jnp.where(iota == labels[..., None], logits, 0.0), axis=-1
    )
    valid = labels >= 0
    ce = jnp.sum(jnp.where(valid, lse - picked, 0.0))
    denom = jnp.maximum(jnp.sum(valid), 1).astype(jnp.float32)
    return ce, denom


# --------------------------------------------------------------------- #
# decode: per-layer python loop with per-layer cache shapes
# --------------------------------------------------------------------- #
def _layer_meta_py(cfg, i: int) -> Dict[str, Any]:
    theta, window = cfg.rope_theta, cfg.window
    if cfg.local_global_pattern is not None:
        loc, glob = cfg.local_global_pattern
        is_global = (i % (loc + glob)) == (loc + glob - 1)
        window = None if is_global else cfg.window
        if is_global and cfg.rope_theta_global is not None:
            theta = cfg.rope_theta_global
    return {"theta": theta, "window": window}


def init_decode_state(cfg, batch: int, kv_len: int):
    """Per-layer cache list; window layers get window-sized caches."""
    dtype = L.dtype_of(cfg.dtype)
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    caches: List[Dict[str, Any]] = []
    axes: List[Dict[str, Any]] = []
    kv_axes = ("cache_batch", "kv_heads", "cache_seq", "head_dim")
    for i in range(cfg.n_layers):
        meta = _layer_meta_py(cfg, i)
        c: Dict[str, Any] = {}
        a: Dict[str, Any] = {}
        if cfg.family in ("dense", "vlm", "moe", "hybrid"):
            S_i = kv_len if meta["window"] is None else min(meta["window"], kv_len)
            shape = (batch, Hkv, S_i, Dh)
            c["k"], c["v"] = jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
            a["k"] = a["v"] = kv_axes
        if cfg.family in ("ssm", "hybrid"):
            sc, sa = S.init_ssm_cache(cfg, batch, dtype)
            c["ssm"], a["ssm"] = sc, sa
        caches.append(c)
        axes.append(a)
    return caches, axes


def lm_decode_step(params, cfg, caches, token, pos, mesh=None, active=None):
    """token: (b, 1) int32; pos: scalar or (b,) int32; active: optional
    (b,) bool mask (continuous batching) -> (logits (b, vp), caches)."""
    compute_dtype = L.dtype_of(cfg.dtype)
    x = L.embed(params["embed"], token, compute_dtype)
    # weight-stationary decode: activations carry the FSDP (data) shard of
    # the embed dim so each layer contracts against its local weight shard
    # (all-reduce of (b,1,·) partials) instead of all-gathering GBs of
    # weights per token — §Perf iteration 2
    x = shard_activation(x, (None, None, "act_decode_embed"))
    new_caches = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda v: v[i], params["layers"])
        meta = _layer_meta_py(cfg, i)
        c = dict(caches[i])
        fam = cfg.family
        if fam in ("dense", "vlm", "moe"):
            h = L.rms_norm(x, lp["ln_attn"]["w"], cfg.norm_eps)
            windowed = meta["window"] is not None and c["k"].shape[2] <= meta["window"]
            y, c["k"], c["v"] = A.decode_attention_block(
                lp["attn"], h, c["k"], c["v"], pos, cfg,
                theta=meta["theta"], window=meta["window"],
                compute_dtype=compute_dtype, windowed_cache=windowed,
                active=active,
            )
            x = x + y
            h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
            if fam == "moe":
                y, _ = M.moe_block(lp["moe"], h, cfg, compute_dtype, mesh)
                x = x + y
            else:
                x = x + L.swiglu(lp["mlp"], h, compute_dtype)
        elif fam == "ssm":
            h = L.rms_norm(x, lp["ln_ssm"]["w"], cfg.norm_eps)
            y, c["ssm"] = S.mamba2_decode(lp["ssm"], h, c["ssm"], cfg, compute_dtype,
                                          active=active)
            x = x + y
        elif fam == "hybrid":
            h = L.rms_norm(x, lp["ln_attn"]["w"], cfg.norm_eps)
            windowed = meta["window"] is not None and c["k"].shape[2] <= meta["window"]
            att, c["k"], c["v"] = A.decode_attention_block(
                lp["attn"], h, c["k"], c["v"], pos, cfg,
                theta=meta["theta"], window=meta["window"],
                compute_dtype=compute_dtype, windowed_cache=windowed,
                active=active,
            )
            ssm, c["ssm"] = S.mamba2_decode(lp["ssm"], h, c["ssm"], cfg, compute_dtype,
                                            active=active)
            x = x + 0.5 * (
                L.rms_norm(att, lp["comb"]["norm_attn"], cfg.norm_eps)
                + L.rms_norm(ssm, lp["comb"]["norm_ssm"], cfg.norm_eps)
            )
            h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
            x = x + L.swiglu(lp["mlp"], h, compute_dtype)
        x = shard_activation(x, (None, None, "act_decode_embed"))
        new_caches.append(c)
    x = L.rms_norm(x, params["ln_f"]["w"], cfg.norm_eps)
    logits = _head(params, cfg, x, compute_dtype)[:, 0]
    return logits, new_caches
