"""``--arch <id>`` -> unified model API (init / loss / forward / decode)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from . import encdec as ED
from . import transformer as T


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: Any
    init: Callable          # (key) -> (params, axes)
    loss: Callable           # (params, batch, mesh) -> (loss, metrics)
    forward: Callable        # (params, batch, mesh) -> logits  (prefill)
    decode_init: Callable    # (batch, kv_len) -> (caches, axes)
    decode_step: Callable    # (params, caches, token, pos, mesh) -> (logits, caches)


def build_model(cfg) -> ModelAPI:
    if cfg.family == "audio":
        def fwd(params, batch, mesh=None):
            enc = ED.encode(params, cfg, batch["frames"], mesh)
            return ED.decode_train(params, cfg, batch["tokens"], enc, mesh)

        return ModelAPI(
            cfg=cfg,
            init=lambda key: ED.init_encdec(cfg, key),
            loss=lambda params, batch, mesh=None: ED.encdec_loss(params, cfg, batch, mesh),
            forward=fwd,
            decode_init=lambda batch, kv_len: ED.init_decode_state(cfg, batch, kv_len),
            decode_step=lambda params, caches, token, pos, mesh=None, active=None:
                ED.encdec_decode_step(params, cfg, caches, token, pos, mesh, active),
        )

    def fwd(params, batch, mesh=None):
        logits, _, n_prefix = T.lm_forward(
            params, cfg, batch["tokens"], mesh, batch.get("patches")
        )
        return logits

    return ModelAPI(
        cfg=cfg,
        init=lambda key: T.init_lm(cfg, key),
        loss=lambda params, batch, mesh=None: T.lm_loss(params, cfg, batch, mesh),
        forward=fwd,
        decode_init=lambda batch, kv_len: T.init_decode_state(cfg, batch, kv_len),
        decode_step=lambda params, caches, token, pos, mesh=None, active=None:
            T.lm_decode_step(params, cfg, caches, token, pos, mesh, active),
    )
