"""Mamba-2 mixer: chunked SSD (state-space duality) + O(1) decode.

Train/prefill uses the SSD block decomposition (Dao & Gu, 2024): within a
chunk the recurrence is the masked-attention dual (an (L, L) decay-weighted
C·Bᵀ product — MXU work); across chunks a small (H, N, P) state is carried
by an associative scan.  Decode keeps the recurrent form: one (N, P) state
update per head per token — this is what makes the ``long_500k`` shape
feasible for SSM/hybrid archs.

ngroups = 1 (B and C shared across heads), headdim P = cfg.ssm_head_dim,
inner width Di = expand * d_model, H = Di / P heads.  The sequential
recurrence in ``ssd_ref`` is the test oracle.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers as L


def init_mamba2(key, cfg, dtype=jnp.float32):
    E, Di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.ssm_conv
    std = L.fan_in_std(E)
    return L.declare(key, {
        # order: [z(Di) | x(Di) | B(N) | C(N) | dt(H)]
        "w_in": ((E, 2 * Di + 2 * N + H), ("embed", "ssm_inner"), std),
        "conv_w": ((Di + 2 * N, K), ("ssm_inner", "conv"), L.fan_in_std(K)),
        "conv_b": ((Di + 2 * N,), ("ssm_inner",), 0.0),
        "dt_bias": ((H,), ("ssm_heads",), 0.0),
        "A_log": ((H,), ("ssm_heads",), -0.5),   # A = -exp(A_log) ≈ -0.6
        "D": ((H,), ("ssm_heads",), -1.0),       # constant 1.0
        "norm": ((Di,), ("ssm_inner",), 0.0),
        "w_out": ((Di, E), ("ssm_inner", "embed"), L.fan_in_std(Di)),
    }, dtype)


def _split_proj(p, u, cfg, compute_dtype):
    Di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bse,ei->bsi", u, p["w_in"].astype(compute_dtype))
    z = zxbcdt[..., :Di]
    xbc = zxbcdt[..., Di : 2 * Di + 2 * N]
    dt = zxbcdt[..., 2 * Di + 2 * N :]
    return z, xbc, dt


def _causal_conv(p, xbc, compute_dtype):
    """Depthwise causal conv, kernel K, over (b, s, ch)."""
    K = p["conv_w"].shape[1]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * p["conv_w"][:, i].astype(compute_dtype)
        for i in range(K)
    )
    return jax.nn.silu(
        (out + p["conv_b"].astype(compute_dtype)).astype(jnp.float32)
    ).astype(compute_dtype)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD over full sequences.

    x: (b, s, H, P); dt: (b, s, H); A: (H,) negative; B, C: (b, s, N).
    Returns y: (b, s, H, P) and final state (b, H, N, P).
    """
    b, s, H, P = x.shape
    N = B.shape[-1]
    pad = -s % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    # chunk-major for the scan: (nc, b, L, ...)
    xc = x.reshape(b, nc, chunk, H, P).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, H).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(S_in, inp):
        xi, dti, Bi, Ci = inp                    # (b, L, ...)
        cum = jnp.cumsum(dti * A[None, None, :], axis=1)  # (b, L, H)
        dtx = xi * dti[..., None]                # (b, L, H, P)
        # intra-chunk (dual / attention-like) term; (b,L,L,H) gate lives
        # only for this scan step
        sc = jnp.einsum("bin,bjn->bij", Ci, Bi)
        gate = jnp.where(
            tri[None, :, :, None],
            jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]),
            0.0,
        )
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", sc, gate, dtx)
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", Ci, S_in, jnp.exp(cum))
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)      # (b, L, H)
        S_out = S_in * jnp.exp(cum[:, -1, :])[..., None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", Bi, decay_to_end, dtx
        )
        return S_out, y_intra + y_inter

    S0 = jnp.zeros((b, H, N, P), jnp.float32)
    S_final, ys = jax.lax.scan(step, S0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s + pad, H, P)[:, :s]
    return y, S_final


def ssd_ref(x, dt, A, B, C):
    """Sequential recurrence oracle: S_t = exp(A dt_t) S + dt_t B_t xᵀ_t."""
    b, s, H, P = x.shape
    N = B.shape[-1]

    def step(S, inp):
        xt, dtt, Bt, Ct = inp  # (b,H,P), (b,H), (b,N), (b,N)
        decay = jnp.exp(dtt * A[None])  # (b,H)
        S = S * decay[..., None, None] + jnp.einsum(
            "bn,bhp,bh->bhnp", Bt, xt, dtt
        )
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((b, H, N, P), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B.transpose(1, 0, 2).astype(jnp.float32),
        C.transpose(1, 0, 2).astype(jnp.float32),
    )
    S, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3), S


def mamba2_block(p, u, cfg, compute_dtype, chunk: int = 256):
    """Full mixer: u (b, s, E) -> (b, s, E)."""
    Di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    b, s, _ = u.shape
    z, xbc, dt = _split_proj(p, u, cfg, compute_dtype)
    xbc = _causal_conv(p, xbc, compute_dtype)
    x = xbc[..., :Di].reshape(b, s, H, P)
    B = xbc[..., Di : Di + N]
    C = xbc[..., Di + N :]
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(x, dt, A, B, C, chunk=min(chunk, s))
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, Di).astype(compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype)
    y = L.rms_norm(y, p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,ie->bse", y, p["w_out"].astype(compute_dtype))


# --------------------------------------------------------------------- #
# decode path: O(1) state update per token
# --------------------------------------------------------------------- #
def init_ssm_cache(cfg, batch: int, dtype):
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    Di = cfg.d_inner
    K = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, Di + 2 * N), dtype),
    }, {
        "state": ("cache_batch", "ssm_heads", "ssm_state", None),
        "conv": ("cache_batch", "conv", "ssm_inner"),
    }


def mamba2_decode(p, u, cache, cfg, compute_dtype, active=None):
    """u: (b, 1, E); cache: {'state','conv'} -> (y, new_cache).
    ``active``: optional (b,) bool; inactive rows keep their state."""
    Di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    b = u.shape[0]
    z, xbc, dt = _split_proj(p, u, cfg, compute_dtype)  # (b,1,·)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # (b, K, ch)
    conv_out = jnp.einsum("bkc,ck->bc", hist, p["conv_w"].astype(compute_dtype))
    conv_out = jax.nn.silu(
        (conv_out + p["conv_b"].astype(compute_dtype)).astype(jnp.float32)
    )
    x = conv_out[:, :Di].reshape(b, H, P)
    B = conv_out[:, Di : Di + N]
    C = conv_out[:, Di + N :]
    dts = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )  # (b, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dts * A[None])
    S = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", B, x, dts
    )
    y = jnp.einsum("bn,bhnp->bhp", C, S)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x
    y = y.reshape(b, 1, Di).astype(compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(compute_dtype)
    y = L.rms_norm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,ie->bse", y, p["w_out"].astype(compute_dtype))
    new_state, new_conv = S, hist[:, 1:]
    if active is not None:
        new_state = jnp.where(active[:, None, None, None], new_state, cache["state"])
        new_conv = jnp.where(active[:, None, None], new_conv, cache["conv"])
    return out, {"state": new_state, "conv": new_conv}
