"""Common layers: norms, RoPE, MLPs, embeddings, param declaration.

Parameters are plain nested dicts of arrays; every init function returns a
matching tree of *logical axis tuples* used by ``repro.sharding`` to derive
PartitionSpecs.  Layer stacks are built by vmapping init over a leading
``layers`` axis so the forward pass can ``lax.scan`` over them.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------- #
# param declaration
# --------------------------------------------------------------------- #
def declare(key, decls: Dict[str, Tuple[Tuple[int, ...], Tuple, float]],
            dtype=jnp.float32):
    """decls: name -> (shape, logical_axes, init_std). std 0 => zeros,
    std < 0 => constant |std|."""
    params, axes = {}, {}
    keys = jax.random.split(key, max(len(decls), 1))
    for (name, (shape, ax, std)), k in zip(decls.items(), keys):
        if std == 0.0:
            params[name] = jnp.zeros(shape, dtype)
        elif std < 0.0:
            params[name] = jnp.full(shape, -std, dtype)
        else:
            params[name] = jax.random.normal(k, shape, dtype) * std
        axes[name] = ax
    return params, axes


def fan_in_std(fan_in: int) -> float:
    return 1.0 / math.sqrt(max(fan_in, 1))


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# rotary position embeddings
# --------------------------------------------------------------------- #
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x: (..., seq, head_dim); positions: (..., seq) int; theta scalar or
    traced scalar (per-layer inside scans)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32))
        * (jnp.arange(half, dtype=jnp.float32) / half)
    )
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------- #
def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32):
    return declare(key, {
        "w_gate": ((d_model, d_ff), ("embed", "mlp"), fan_in_std(d_model)),
        "w_up": ((d_model, d_ff), ("embed", "mlp"), fan_in_std(d_model)),
        "w_down": ((d_ff, d_model), ("mlp", "embed"), fan_in_std(d_ff)),
    }, dtype)


def swiglu(p, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    g = jnp.einsum("...e,ef->...f", x, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("...e,ef->...f", x, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    return jnp.einsum("...f,fe->...e", h, p["w_down"].astype(compute_dtype))


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    return declare(key, {
        "w_in": ((d_model, d_ff), ("embed", "mlp"), fan_in_std(d_model)),
        "b_in": ((d_ff,), ("mlp",), 0.0),
        "w_out": ((d_ff, d_model), ("mlp", "embed"), fan_in_std(d_ff)),
        "b_out": ((d_model,), ("embed_r",), 0.0),
    }, dtype)


def gelu_mlp(p, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    h = jnp.einsum("...e,ef->...f", x, p["w_in"].astype(compute_dtype))
    h = jax.nn.gelu((h + p["b_in"].astype(compute_dtype)).astype(jnp.float32))
    out = jnp.einsum("...f,fe->...e", h.astype(compute_dtype),
                     p["w_out"].astype(compute_dtype))
    return out + p["b_out"].astype(compute_dtype)


# --------------------------------------------------------------------- #
# embeddings / heads
# --------------------------------------------------------------------- #
def init_embedding(key, vocab_padded: int, d_model: int, dtype=jnp.float32):
    # table replicated over data, sharded over model on the embed dim so the
    # token gather stays local (DESIGN.md §4)
    return declare(key, {
        "table": ((vocab_padded, d_model), (None, "act_mlp"), 1.0),
    }, dtype)


def embed(p, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    # pin shardings around the gather: tokens replicated over `model`,
    # output sharded on the embed dim (matches the table) — leaving this
    # to sharding propagation trips an SPMD partitioner bug (invalid
    # dynamic-slice) when the gather sits under jvp + microbatching.
    from ..sharding import shard_activation

    tokens = shard_activation(tokens, ("batch", None))
    out = jnp.take(p["table"].astype(compute_dtype), tokens, axis=0)
    return shard_activation(out, ("batch", None, "act_mlp"))


def init_lm_head(key, d_model: int, vocab_padded: int, dtype=jnp.float32):
    return declare(key, {
        "w": ((d_model, vocab_padded), ("embed_r", "vocab"), fan_in_std(d_model)),
    }, dtype)


def lm_head(p, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.einsum("...e,ev->...v", x, p["w"].astype(compute_dtype))


def stack_layers(init_fn, key, n_layers: int):
    """vmap an init over a leading layers axis; returns (params, axes) with
    the ``layers`` logical axis prepended to every leaf."""
    keys = jax.random.split(key, n_layers)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(key)
    axes = jax.tree.map(
        lambda ax: ("layers",) + ax,
        axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )
    return params, axes
