"""Whisper-style encoder–decoder backbone.

The audio conv frontend is a STUB per the brief: ``input_specs`` feeds
precomputed frame embeddings (b, s_frames, d_model); a linear adapter
stands in for the conv stack.  Positions are sinusoidal (whisper's encoder
choice; we use it on both sides — a documented simplification), norms are
RMSNorm for substrate uniformity.

Shapes policy for the assigned grid (DESIGN.md §4): ``train_4k`` /
``prefill_32k`` run the encoder over ``seq_len`` frames and the decoder
over ``seq_len // 4`` text tokens; decode shapes exercise one token against
a ``seq_len`` self-attention cache plus a fixed 1500-frame cross cache.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..sharding import shard_activation
from . import attention as A
from . import layers as L

CROSS_LEN = 1500  # whisper's fixed 30 s encoder length


def _sinusoid(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_cross_attention(key, cfg, dtype):
    E, Hq, Hkv, Dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    std = L.fan_in_std(E)
    return L.declare(key, {
        "wq": ((E, Hq, Dh), ("embed", "heads", "head_dim"), std),
        "wk": ((E, Hkv, Dh), ("embed", "kv_heads", "head_dim"), std),
        "wv": ((E, Hkv, Dh), ("embed", "kv_heads", "head_dim"), std),
        "wo": ((Hq, Dh, E), ("heads", "head_dim", "embed"), L.fan_in_std(Hq * Dh)),
    }, dtype)


def _init_enc_layer(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["attn"], a["attn"] = A.init_attention(ks[0], cfg, dtype)
    p["ln_attn"], a["ln_attn"] = L.declare(ks[1], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    p["mlp"], a["mlp"] = L.init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
    p["ln_mlp"], a["ln_mlp"] = L.declare(ks[3], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    return p, a


def _init_dec_layer(key, cfg, dtype):
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["self"], a["self"] = A.init_attention(ks[0], cfg, dtype)
    p["ln_self"], a["ln_self"] = L.declare(ks[1], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    p["cross"], a["cross"] = _init_cross_attention(ks[2], cfg, dtype)
    p["ln_cross"], a["ln_cross"] = L.declare(
        ks[3], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    p["mlp"], a["mlp"] = L.init_gelu_mlp(ks[4], cfg.d_model, cfg.d_ff, dtype)
    p["ln_mlp"], a["ln_mlp"] = L.declare(ks[5], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    return p, a


def init_encdec(cfg, key):
    dtype = L.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    params, axes = {}, {}
    params["frontend"], axes["frontend"] = L.declare(ks[0], {
        "w": ((cfg.d_model, cfg.d_model), (None, "act_mlp"), L.fan_in_std(cfg.d_model)),
    }, dtype)
    params["embed"], axes["embed"] = L.init_embedding(ks[1], cfg.padded_vocab, cfg.d_model, dtype)
    params["enc_layers"], axes["enc_layers"] = L.stack_layers(
        lambda k: _init_enc_layer(k, cfg, dtype), ks[2], cfg.n_encoder_layers)
    params["dec_layers"], axes["dec_layers"] = L.stack_layers(
        lambda k: _init_dec_layer(k, cfg, dtype), ks[3], cfg.n_layers)
    params["ln_enc"], axes["ln_enc"] = L.declare(
        ks[4], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    params["ln_f"], axes["ln_f"] = L.declare(
        ks[4], {"w": ((cfg.d_model,), ("embed_r",), 0.0)}, dtype)
    params["head"], axes["head"] = L.init_lm_head(ks[5], cfg.d_model, cfg.padded_vocab, dtype)
    return params, axes


def _cross_attention(p, x, enc_k, enc_v, cfg, compute_dtype):
    """x: (b, sq, E); enc_k/v: (b, hkv, s_enc, dh)."""
    q = jnp.einsum("bse,ehd->bhsd", x, p["wq"].astype(compute_dtype))
    from .attention import chunked_attention

    out = chunked_attention(q, enc_k, enc_v, causal=False, window=None,
                            chunk=cfg.attn_chunk)
    return jnp.einsum("bhsd,hde->bse", out, p["wo"].astype(compute_dtype))


def _enc_kv(p, enc_out, compute_dtype):
    k = jnp.einsum("bse,ehd->bhsd", enc_out, p["wk"].astype(compute_dtype))
    v = jnp.einsum("bse,ehd->bhsd", enc_out, p["wv"].astype(compute_dtype))
    return k, v


def encode(params, cfg, frames, mesh=None):
    compute_dtype = L.dtype_of(cfg.dtype)
    x = jnp.einsum("bse,ed->bsd", frames.astype(compute_dtype),
                   params["frontend"]["w"].astype(compute_dtype))
    x = x + _sinusoid(jnp.arange(x.shape[1])[None], cfg.d_model).astype(compute_dtype)
    x = shard_activation(x, ("batch", None, "act_embed"))

    def body(x, lp):
        h = L.rms_norm(x, lp["ln_attn"]["w"], cfg.norm_eps)
        x = x + A.attention_block(lp["attn"], h, cfg, theta=None, window=None,
                                  compute_dtype=compute_dtype, causal=False)
        h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
        x = x + L.gelu_mlp(lp["mlp"], h, compute_dtype)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.rms_norm(x, params["ln_enc"]["w"], cfg.norm_eps)


def decode_train(params, cfg, tokens, enc_out, mesh=None):
    compute_dtype = L.dtype_of(cfg.dtype)
    x = L.embed(params["embed"], tokens, compute_dtype)
    x = x + _sinusoid(jnp.arange(x.shape[1])[None], cfg.d_model).astype(compute_dtype)
    x = shard_activation(x, ("batch", None, "act_embed"))

    def body(x, lp):
        h = L.rms_norm(x, lp["ln_self"]["w"], cfg.norm_eps)
        x = x + A.attention_block(lp["self"], h, cfg, theta=None, window=None,
                                  compute_dtype=compute_dtype, causal=True)
        h = L.rms_norm(x, lp["ln_cross"]["w"], cfg.norm_eps)
        ek, ev = _enc_kv(lp["cross"], enc_out, compute_dtype)
        x = x + _cross_attention(lp["cross"], h, ek, ev, cfg, compute_dtype)
        h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
        x = x + L.gelu_mlp(lp["mlp"], h, compute_dtype)
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = L.rms_norm(x, params["ln_f"]["w"], cfg.norm_eps)
    return L.lm_head(params["head"], x, compute_dtype)


def encdec_loss(params, cfg, batch, mesh=None):
    from .transformer import _ce

    enc_out = encode(params, cfg, batch["frames"], mesh)
    logits = decode_train(params, cfg, batch["tokens"], enc_out, mesh)
    logits = shard_activation(logits, ("batch", None, "act_vocab"))
    ce, denom = _ce(logits, batch["labels"], cfg)
    return ce / denom, {"ce": ce / denom, "tokens": denom}


# --------------------------------------------------------------------- #
# decode: self cache per layer + precomputed cross k/v
# --------------------------------------------------------------------- #
def init_decode_state(cfg, batch: int, kv_len: int, cross_len: int = CROSS_LEN):
    dtype = L.dtype_of(cfg.dtype)
    Hkv, Dh = cfg.n_kv_heads, cfg.resolved_head_dim
    kv_axes = ("cache_batch", "kv_heads", "cache_seq", "head_dim")
    caches, axes = [], []
    for _ in range(cfg.n_layers):
        shape = (batch, Hkv, kv_len, Dh)
        xshape = (batch, Hkv, cross_len, Dh)
        caches.append({
            "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
        })
        axes.append({"k": kv_axes, "v": kv_axes,
                     "xk": kv_axes, "xv": kv_axes})
    return caches, axes


def encdec_decode_step(params, cfg, caches, token, pos, mesh=None, active=None):
    compute_dtype = L.dtype_of(cfg.dtype)
    b = token.shape[0]
    x = L.embed(params["embed"], token, compute_dtype)
    pos_vec = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))
    x = x + _sinusoid(pos_vec[:, None], cfg.d_model).astype(compute_dtype)
    new_caches = []
    for i in range(cfg.n_layers):
        lp = jax.tree.map(lambda v: v[i], params["dec_layers"])
        c = dict(caches[i])
        h = L.rms_norm(x, lp["ln_self"]["w"], cfg.norm_eps)
        y, c["k"], c["v"] = A.decode_attention_block(
            lp["self"], h, c["k"], c["v"], pos, cfg,
            theta=None, window=None, compute_dtype=compute_dtype,
            active=active,
        )
        x = x + y
        h = L.rms_norm(x, lp["ln_cross"]["w"], cfg.norm_eps)
        x = x + _cross_attention(lp["cross"], h, c["xk"], c["xv"], cfg, compute_dtype)
        h = L.rms_norm(x, lp["ln_mlp"]["w"], cfg.norm_eps)
        x = x + L.gelu_mlp(lp["mlp"], h, compute_dtype)
        new_caches.append(c)
    x = L.rms_norm(x, params["ln_f"]["w"], cfg.norm_eps)
    logits = L.lm_head(params["head"], x, compute_dtype)[:, 0]
    return logits, new_caches
