"""Expert-parallel MoE block (shard_map + all_to_all dispatch).

Routing is computed locally per data shard; tokens are capacity-padded into
an (experts, capacity, d_model) buffer and exchanged with the expert owners
via ``lax.all_to_all`` over the ``model`` axis — the canonical EP collective
pattern.  Requires n_experts % model_axis == 0; otherwise (and on meshes
without a ``model`` axis) the exact dense-dispatch reference below is used,
which is also the test oracle.

Capacity drops follow the standard top-k-then-truncate rule; the combine is
a weighted scatter-add, so dropped tokens contribute zero (residual carries
them).  An auxiliary load-balancing loss (Shazeer-style) is returned for
the trainer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.4.35 exposes shard_map at the top level
    shard_map = jax.shard_map
except AttributeError:  # older jax: experimental namespace + old kwarg name
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, /, **kw):
        kw["check_rep"] = kw.pop("check_vma", False)
        return _shard_map(f, **kw)

from . import layers as L


def init_moe(key, cfg, dtype=jnp.float32):
    E, F, X = cfg.d_model, cfg.d_ff, cfg.n_experts
    std = L.fan_in_std(E)
    return L.declare(key, {
        "router": ((E, X), ("embed_r", None), std),
        "w_gate": ((X, E, F), ("experts", "embed", "mlp"), std),
        "w_up": ((X, E, F), ("experts", "embed", "mlp"), std),
        "w_down": ((X, F, E), ("experts", "mlp", "embed"), L.fan_in_std(F)),
    }, dtype)


def _expert_ffn(w_gate, w_up, w_down, x, compute_dtype, psum_axis=None):
    # x: (X_local, C, E) — E may be a local shard (weight-stationary
    # decode): contract the local slice and psum the partials.
    g = jnp.einsum("xce,xef->xcf", x, w_gate.astype(compute_dtype))
    u = jnp.einsum("xce,xef->xcf", x, w_up.astype(compute_dtype))
    if psum_axis is not None:
        g = jax.lax.psum(g, psum_axis)
        u = jax.lax.psum(u, psum_axis)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    return jnp.einsum("xcf,xfe->xce", h, w_down.astype(compute_dtype))


def _aux_loss(probs: jnp.ndarray, expert_idx: jnp.ndarray, n_experts: int):
    """Load-balance loss: X * sum_e f_e * P_e (f = token fraction routed)."""
    X = n_experts
    one_hot = jax.nn.one_hot(expert_idx, X, dtype=jnp.float32)  # (..., k, X)
    f = one_hot.sum(axis=-2).reshape(-1, X).mean(axis=0)
    p = probs.reshape(-1, X).mean(axis=0)
    return X * jnp.sum(f * p)


def moe_block_dense(p, x, cfg, compute_dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact dense-dispatch reference: every expert sees every token."""
    probs = jax.nn.softmax(
        jnp.einsum("bse,ex->bsx", x.astype(jnp.float32),
                   p["router"].astype(jnp.float32)), axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None, None],
        jnp.arange(x.shape[1])[None, :, None],
        idx,
    ].set(vals)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    g = jnp.einsum("bse,xef->bsxf", x, p["w_gate"].astype(compute_dtype))
    u = jnp.einsum("bse,xef->bsxf", x, p["w_up"].astype(compute_dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    y = jnp.einsum("bsxf,xfe->bsxe", h, p["w_down"].astype(compute_dtype))
    out = jnp.einsum("bsxe,bsx->bse", y, gates.astype(compute_dtype))
    return out, _aux_loss(probs, idx, cfg.n_experts)


def _local_dispatch_combine(p, x, cfg, compute_dtype, ep_size: int,
                            dp_axes: tuple, gather_axes: dict,
                            weight_stationary: bool = False):
    """Body run per (pod, data, model) shard inside shard_map.

    Two weight-consumption modes:
      * train/prefill: ZeRO-3 gather — expert weights arrive sharded over
        `data` on their embed/mlp dims; cast to compute dtype BEFORE the
        all-gather (bf16 wire/temp, 2x cheaper), gather, contract locally.
      * decode (weight_stationary): DON'T gather — x arrives with its
        embed dim sharded over `data`; contract the local E slice and
        psum partials.  Per-token weight movement drops from O(params) to
        O(activations) (EXPERIMENTS §Perf iteration 1c).
    """
    p = dict(p)
    psum_axis = None
    if weight_stationary:
        psum_axis = "data" if gather_axes else None
    else:
        for name, dim in gather_axes.items():
            p[name] = jax.lax.all_gather(
                p[name].astype(compute_dtype), "data", axis=dim, tiled=True
            )
    b, s, E = x.shape  # E is the LOCAL embed width in weight-stationary mode
    X, k = cfg.n_experts, cfg.top_k
    T = b * s
    xf = x.reshape(T, E)
    if psum_axis is not None:
        # router table is replicated; x's E dim is this shard's slice —
        # contract against the matching router rows and psum the partials
        idx = jax.lax.axis_index(psum_axis)
        router_rows = jax.lax.dynamic_slice_in_dim(
            p["router"].astype(jnp.float32), idx * E, E, 0
        )
        router_logits = jax.lax.psum(
            jnp.einsum("te,ex->tx", xf.astype(jnp.float32), router_rows),
            psum_axis,
        )
    else:
        router_logits = jnp.einsum(
            "te,ex->tx", xf.astype(jnp.float32), p["router"].astype(jnp.float32)
        )
    probs = jax.nn.softmax(router_logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    vals = vals / (vals.sum(-1, keepdims=True) + 1e-9)
    aux = _aux_loss(probs, idx, X)
    aux = jax.lax.pmean(aux, dp_axes + ("model",) if dp_axes else ("model",))

    e_flat = idx.reshape(-1)                       # (T*k,)
    t_flat = jnp.repeat(jnp.arange(T), k)
    w_flat = vals.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=X)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[e_s]
    C = int(max(1, -(-T * k // X) * cfg.capacity_factor))
    keep = pos < C

    buf = jnp.zeros((X, C, E), compute_dtype)
    buf = buf.at[
        jnp.where(keep, e_s, 0), jnp.where(keep, pos, 0)
    ].add(jnp.where(keep[:, None], xf[t_s], 0).astype(compute_dtype))

    if ep_size > 1:
        # (X, C, E) -> (X/ep, C*ep, E): tokens for my experts from all peers
        buf = jax.lax.all_to_all(
            buf, "model", split_axis=0, concat_axis=1, tiled=True
        )
    h = _expert_ffn(p["w_gate"], p["w_up"], p["w_down"], buf, compute_dtype,
                    psum_axis=psum_axis)
    if ep_size > 1:
        h = jax.lax.all_to_all(
            h, "model", split_axis=1, concat_axis=0, tiled=True
        )
    # combine: weighted gather back to token order
    gathered = h[jnp.where(keep, e_s, 0), jnp.where(keep, pos, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = jnp.zeros((T, E), jnp.float32).at[t_s].add(
        gathered.astype(jnp.float32) * w_s[:, None]
    )
    return y.astype(compute_dtype).reshape(b, s, E), aux


def moe_block(p, x, cfg, compute_dtype, mesh: Mesh | None):
    """EP MoE; falls back to dense dispatch off-mesh or when experts don't
    divide the model axis."""
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return moe_block_dense(p, x, cfg, compute_dtype)
    ep = mesh.shape["model"]
    if cfg.n_experts % ep != 0:
        return moe_block_dense(p, x, cfg, compute_dtype)

    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # in_specs must MATCH the storage sharding (experts -> model, embed/mlp
    # FSDP'd over data); a mismatch makes the SPMD partitioner insert
    # pathological reshards at the shard_map boundary.
    from ..sharding import logical_to_spec

    w_axes = {
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    pspecs = {"router": P()}
    gather_axes = {}
    for name, axes in w_axes.items():
        spec = logical_to_spec(axes, p[name].shape, mesh)
        pspecs[name] = spec
        for dim, entry in enumerate(spec):
            entries = entry if isinstance(entry, tuple) else (entry,)
            if "data" in entries:
                gather_axes[name] = dim
    # Route only the local sequence slice per model shard: with tokens
    # replicated over `model`, every shard would route (and the expert
    # owners would compute) the SAME tokens ep× over — measured 16×
    # redundant expert FLOPs on dbrx-132b before this split.
    s = x.shape[1]
    seq_split = s % ep == 0 and s >= ep
    # decode (s == 1): weight-stationary mode — x carries the data-shard
    # of its embed dim; expert weights are never gathered (per-token
    # weight movement O(params) -> O(activations)).
    dsz = mesh.shape.get("data", 1)
    weight_stationary = (
        s == 1 and bool(gather_axes) and x.shape[-1] % dsz == 0 and dsz > 1
    )
    body = functools.partial(
        _local_dispatch_combine, cfg=cfg, compute_dtype=compute_dtype,
        ep_size=ep, dp_axes=dp_axes, gather_axes=gather_axes,
        weight_stationary=weight_stationary,
    )
    if weight_stationary:
        x_spec = P(None, None, "data")
    else:
        x_spec = P(dp_axes, "model" if seq_split else None, None)
    fn = shard_map(
        lambda pp, xx: body(pp, xx),
        mesh=mesh,
        in_specs=(pspecs, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    y, aux = fn({k: p[k] for k in pspecs}, x)
    return y, aux
