# Model substrate: layers, attention, MoE, SSM, decoder LM, enc-dec, VLM.
