from .axes import (  # noqa: F401
    LOGICAL_RULES,
    logical_to_spec,
    shard_activation,
    spec_tree,
)
