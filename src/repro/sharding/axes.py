"""Logical-axis sharding (MaxText-style) with divisibility guards.

Every parameter/activation dimension carries a *logical* name; rules map
logical names to mesh axes.  A mesh axis is applied only when the dimension
size divides the axis extent — otherwise the dim stays replicated (e.g.
Hymba's 25 heads or Whisper's 12 heads on a 16-wide ``model`` axis), which
keeps every assigned architecture lowerable on the production mesh without
per-arch hand-tuning.

Parallelism map (mesh axes ``pod``, ``data``, ``model``):
  DP   : ``batch -> (pod, data)``
  FSDP : ``embed -> data``  (ZeRO-3: params+optimizer sharded over DP)
  TP   : ``heads/kv_heads/mlp/vocab -> model``
  EP   : ``experts -> model``
  SP   : ``cache_seq -> model`` (sequence-sharded decode attention)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None)
LOGICAL_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",        # FSDP shard of the contracting dim
    "embed_r": None,        # replicated variant (embedding/head tables)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": None,
    "vocab": "model",
    "layers": None,
    "groups": None,
    "conv": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "cache_batch": ("pod", "data"),
    "cache_seq": "model",
    "patch": None,
    "frames": None,
    "act_embed": None,      # activation d_model dim (replicated by default)
    "act_decode_embed": "data",  # decode: embed-sharded activations so the
                                 # FSDP weights are consumed shard-local
                                 # (partial-sum all-reduce ≪ weight gather)
    "act_seq": "model",     # sequence-parallel residual stream (opt-in)
    "act_mlp": "model",     # activation ff dim under TP
    "act_heads": "model",
    "act_vocab": "model",
}


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    dims: Sequence[int],
    mesh: Mesh,
    rules: Optional[Dict[str, Any]] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec, dropping mesh axes
    that don't divide the dimension or don't exist in the mesh."""
    rules = rules if rules is not None else LOGICAL_RULES
    sizes = _mesh_sizes(mesh)
    used = set()
    out = []
    for name, dim in zip(logical_axes, dims):
        if name is None:
            out.append(None)
            continue
        assigned = rules.get(name)
        if assigned is None:
            out.append(None)
            continue
        axes = assigned if isinstance(assigned, tuple) else (assigned,)
        keep = []
        extent = 1
        for ax in axes:
            if ax not in sizes or ax in used:
                continue
            if dim % (extent * sizes[ax]) == 0:
                keep.append(ax)
                extent *= sizes[ax]
        for ax in keep:
            used.add(ax)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    return P(*out)


def spec_tree(
    axes_tree: Any, shape_tree: Any, mesh: Mesh,
    rules: Optional[Dict[str, Any]] = None,
) -> Any:
    """Map a tree of logical-axes tuples + a matching tree of shapes to a
    tree of PartitionSpecs."""
    return jax.tree.map(
        lambda axes, shp: logical_to_spec(axes, shp.shape, mesh, rules),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def shard_activation(x, logical_axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None):
    """with_sharding_constraint by logical names (no-op outside a mesh)."""
    if mesh is None:
        mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return m
    except Exception:  # pragma: no cover
        return None
