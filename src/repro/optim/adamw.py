"""AdamW with decoupled weight decay, global-norm clipping and a
warmup-cosine schedule.  Optimizer state mirrors the parameter tree (same
logical axes ⇒ same sharding: ZeRO-style sharded optimizer for free)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> Dict[str, Any]:
        zeros = lambda t: jax.tree.map(  # noqa: E731
            lambda p: jnp.zeros(p.shape, jnp.float32), t
        )
        return {"m": zeros(params), "v": zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    def state_axes(self, param_axes) -> Dict[str, Any]:
        return {"m": param_axes, "v": param_axes, "step": ()}

    def update(self, grads, state, params) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
        step = state["step"] + 1
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)
        ))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** step.astype(jnp.float32))
            vhat = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            new_p = p.astype(jnp.float32) - lr * (
                delta + self.weight_decay * p.astype(jnp.float32)
            )
            return new_p.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
