from .adamw import AdamW, warmup_cosine  # noqa: F401
