"""String-keyed backend registry: ``ClusterConfig.backend`` -> factory.

Third-party engines plug in with::

    @register_backend("my-engine")
    def _build(cfg: ClusterConfig) -> ClusterIndex:
        return MyIndex(cfg)

and become constructible through ``build_index`` / CLI flags everywhere.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple, Union

from .config import ClusterConfig
from .index import ClusterIndex

Factory = Callable[[ClusterConfig], ClusterIndex]

_REGISTRY: Dict[str, Factory] = {}


def register_backend(name: str,
                     overwrite: bool = False) -> Callable[[Factory], Factory]:
    """Decorator registering a ``cfg -> ClusterIndex`` factory under ``name``.

    Re-registering an existing name raises unless ``overwrite=True`` —
    tests (and e.g. the sharded backend's inner-engine fixtures) use
    ``overwrite=True`` / :func:`unregister_backend` to swap factories.
    """

    def deco(factory: Factory) -> Factory:
        if name in _REGISTRY and not overwrite:
            raise ValueError(
                f"backend {name!r} already registered "
                "(pass overwrite=True to replace it)"
            )
        _REGISTRY[name] = factory
        return factory

    return deco


def unregister_backend(name: str) -> None:
    """Remove ``name`` from the registry; raises KeyError if unknown."""
    try:
        del _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"backend {name!r} is not registered; "
            f"available: {', '.join(available_backends())}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def build_index(cfg: Union[ClusterConfig, str, None] = None,
                **kwargs: Any) -> ClusterIndex:
    """Build a ClusterIndex from a config (or backend name + config kwargs).

    ``build_index(cfg)``, ``build_index("dynamic", d=8, k=10, t=10, eps=0.5)``
    and ``build_index(d=8, ...)`` (default backend) are all accepted.
    """
    if isinstance(cfg, str):
        cfg = ClusterConfig(backend=cfg, **kwargs)
    elif cfg is None:
        cfg = ClusterConfig(**kwargs)
    elif kwargs:
        cfg = cfg.replace(**kwargs)
    try:
        factory = _REGISTRY[cfg.backend]
    except KeyError:
        raise KeyError(
            f"unknown backend {cfg.backend!r}; "
            f"available: {', '.join(available_backends())}"
        ) from None
    return factory(cfg)


def restore_index(snapshot: Dict[str, Any]) -> ClusterIndex:
    """Rebuild a live index from a :meth:`ClusterIndex.snapshot` payload."""
    cfg = ClusterConfig.from_dict(dict(snapshot["config"]))
    index = build_index(cfg)
    index.restore(snapshot)
    return index
