"""``ClusterIndex`` — the one streaming interface over every engine.

The paper defines a single logical operation set (AddPoint / DeletePoint /
GetCluster); this class is that operation set as an API, so consumers
(serving, curation, benchmarks, examples) are written once and the engine
becomes a config key.  Concrete backends adapt the four engines in
``repro.core`` — see :mod:`repro.api.backends`.

Contract notes:
  * point indices are stable integer handles, unique among live points;
  * ``label(idx)`` is the backend's native point query (for the dynamic
    engines: ROOT on the Euler-tour forest, O(log n)); its value is an
    opaque cluster id, only comparable between two live points;
  * ``labels(ids)`` returns a canonical dense labelling with noise = -1,
    deterministic for a given structure state;
  * ``snapshot()`` / ``restore()`` round-trip the full structure through
    fixed-dtype numpy arrays (npz-serialisable — see
    ``repro.checkpoint.CheckpointManager.save_index``).
"""

from __future__ import annotations

import abc
from typing import Any, Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..core.dynamic_dbscan import NOISE, check_unique_ids
from .config import ClusterConfig
from .events import Delete, Insert


class ClusterIndex(abc.ABC):
    NOISE = NOISE

    def __init__(self, cfg: ClusterConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- #
    # mutations
    # ---------------------------------------------------------------- #
    @abc.abstractmethod
    def insert(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        """AddPoint(x) -> stable handle of the new point."""

    @abc.abstractmethod
    def delete(self, idx: int) -> None:
        """DeletePoint(idx); raises KeyError if idx is not live."""

    def insert_batch(self, X: np.ndarray,
                     ids: Optional[Sequence[Optional[int]]] = None) -> List[int]:
        """Insert the rows of X; backends with device hashing override
        this to amortise the hash over the whole batch."""
        X = np.asarray(X, dtype=np.float64)
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError("ids length must match batch size")
        return [
            self.insert(X[j], None if ids is None else ids[j])
            for j in range(X.shape[0])
        ]

    def delete_batch(self, ids: Sequence[int]) -> None:
        """Delete ``ids``; a duplicate id within one call raises KeyError
        naming the offending id (matching ``insert_batch``'s duplicate-pin
        behavior) before any point is removed."""
        check_unique_ids(ids)
        for i in ids:
            self.delete(i)

    def apply(self, updates: Iterable[Any]) -> List[Optional[int]]:
        """Apply a mixed stream of Insert/Delete events in order.

        Returns one entry per event: the assigned handle for an Insert,
        None for a Delete.  Maximal runs of consecutive Inserts are routed
        through :meth:`insert_batch` so batched backends hash each run in
        one kernel call without reordering the stream.
        """
        out: List[Optional[int]] = []
        run_x: List[np.ndarray] = []
        run_ids: List[Optional[int]] = []

        def flush():
            if run_x:
                out.extend(self.insert_batch(np.stack(run_x), ids=run_ids))
                run_x.clear()
                run_ids.clear()

        for ev in updates:
            if isinstance(ev, Insert):
                run_x.append(np.asarray(ev.x, dtype=np.float64))
                run_ids.append(ev.idx)
            elif isinstance(ev, Delete):
                flush()
                self.delete(ev.idx)
                out.append(None)
            else:
                raise TypeError(f"not an Insert/Delete event: {ev!r}")
        flush()
        return out

    # ---------------------------------------------------------------- #
    # queries
    # ---------------------------------------------------------------- #
    @abc.abstractmethod
    def label(self, idx: int) -> int:
        """GetCluster(idx): the point's current cluster id."""

    @abc.abstractmethod
    def labels(self, ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        """Canonical labelling of ``ids`` (default: all live points);
        noise maps to :data:`NOISE` (-1)."""

    @abc.abstractmethod
    def ids(self) -> List[int]:
        """Sorted handles of all live points."""

    @abc.abstractmethod
    def __contains__(self, idx: int) -> bool: ...

    @abc.abstractmethod
    def __len__(self) -> int: ...

    # ---------------------------------------------------------------- #
    # persistence
    # ---------------------------------------------------------------- #
    @abc.abstractmethod
    def _state(self) -> Dict[str, np.ndarray]: ...

    @abc.abstractmethod
    def _load_state(self, state: Dict[str, np.ndarray]) -> None: ...

    def snapshot(self) -> Dict[str, Any]:
        """Serialisable structure state: ``{"config": ..., "state": ...}``
        where every ``state`` value is a fixed-dtype numpy array."""
        return {"config": self.cfg.to_dict(), "state": self._state()}

    def restore(self, snapshot: Dict[str, Any]) -> None:
        """Load a snapshot into this (freshly built, empty) index."""
        cfg = ClusterConfig.from_dict(dict(snapshot["config"]))
        if cfg != self.cfg:
            raise ValueError(
                f"snapshot config {cfg} does not match index config {self.cfg}"
            )
        if len(self):
            raise ValueError("restore() requires an empty index")
        self._load_state(snapshot["state"])

    # ---------------------------------------------------------------- #
    # diagnostics
    # ---------------------------------------------------------------- #
    def check_invariants(self) -> None:
        """Structural self-check; no-op for recompute baselines."""

    def stats(self) -> Dict[str, int]:
        """Backend instrumentation counters (may be empty)."""
        return {}
