"""``ClusterIndex`` — the one streaming interface over every engine.

The paper defines a single logical operation set (AddPoint / DeletePoint /
GetCluster); this class is that operation set as an API, so consumers
(serving, curation, benchmarks, examples) are written once and the engine
becomes a config key.  Concrete backends adapt the four engines in
``repro.core`` — see :mod:`repro.api.backends`.

Contract notes:
  * point indices are stable integer handles, unique among live points;
  * ``label(idx)`` is the backend's native point query (for the dynamic
    engines: ROOT on the Euler-tour forest, O(log n)); its value is an
    opaque cluster id, only comparable between two live points;
  * ``labels(ids)`` returns a canonical dense labelling with noise = -1,
    deterministic for a given structure state;
  * ``snapshot()`` / ``restore()`` round-trip the full structure through
    fixed-dtype numpy arrays (npz-serialisable — see
    ``repro.checkpoint.CheckpointManager.save_index``).
"""

from __future__ import annotations

import abc
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.dynamic_dbscan import NOISE, check_unique_ids
from ..obs import make_obs
from .config import ClusterConfig
from .events import Delete, Insert


class ClusterIndex(abc.ABC):
    NOISE: int = NOISE

    #: True when the backend answers :meth:`component_of` /
    #: :meth:`core_anchor_of` from maintained structure (no recompute) —
    #: the capability the sharded incremental merge path requires of its
    #: inner engines.
    native_component_queries: bool = False

    def __init__(self, cfg: ClusterConfig):
        self.cfg = cfg
        #: per-index observability handle; the shared no-op NULL_OBS
        #: unless ``cfg.obs`` is set (see repro.obs).
        self.obs = make_obs(cfg.obs)

    # ---------------------------------------------------------------- #
    # mutations
    # ---------------------------------------------------------------- #
    @abc.abstractmethod
    def insert(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        """AddPoint(x) -> stable handle of the new point."""

    @abc.abstractmethod
    def delete(self, idx: int) -> None:
        """DeletePoint(idx); raises KeyError if idx is not live."""

    def insert_batch(self, X: np.ndarray,
                     ids: Optional[Sequence[Optional[int]]] = None) -> List[int]:
        """Insert the rows of X; backends with device hashing override
        this to amortise the hash over the whole batch."""
        X = np.asarray(X, dtype=np.float64)
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError("ids length must match batch size")
        return [
            self.insert(X[j], None if ids is None else ids[j])
            for j in range(X.shape[0])
        ]

    def delete_batch(self, ids: Sequence[int]) -> None:
        """Delete ``ids``; a duplicate id within one call raises KeyError
        naming the offending id (matching ``insert_batch``'s duplicate-pin
        behavior) before any point is removed."""
        check_unique_ids(ids)
        for i in ids:
            self.delete(i)

    def apply(self, updates: Iterable[Any]) -> List[Optional[int]]:
        """Apply a mixed stream of Insert/Delete events in order.

        Returns one entry per event: the assigned handle for an Insert,
        None for a Delete.  Maximal runs of consecutive Inserts are routed
        through :meth:`insert_batch` and maximal runs of consecutive
        Deletes through :meth:`delete_batch`, so batched backends hash
        each insert run in one kernel call and sharded backends fan both
        kinds of run out per shard — without reordering the stream.  (A
        duplicate id within one delete run therefore raises *before* any
        of the run is applied, per the ``delete_batch`` contract.)
        """
        out: List[Optional[int]] = []
        run_x: List[np.ndarray] = []
        run_ids: List[Optional[int]] = []
        run_del: List[int] = []

        def flush() -> None:
            if run_x:
                out.extend(self.insert_batch(np.stack(run_x), ids=run_ids))
                run_x.clear()
                run_ids.clear()
            if run_del:
                self.delete_batch(run_del)
                out.extend([None] * len(run_del))
                run_del.clear()

        for ev in updates:
            if isinstance(ev, Insert):
                if run_del:
                    flush()
                run_x.append(np.asarray(ev.x, dtype=np.float64))
                run_ids.append(ev.idx)
            elif isinstance(ev, Delete):
                if run_x:
                    flush()
                run_del.append(ev.idx)
            else:
                raise TypeError(f"not an Insert/Delete event: {ev!r}")
        flush()
        return out

    # ---------------------------------------------------------------- #
    # queries
    # ---------------------------------------------------------------- #
    @abc.abstractmethod
    def label(self, idx: int) -> int:
        """GetCluster(idx): the point's current cluster id."""

    @abc.abstractmethod
    def labels(self, ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        """Canonical labelling of ``ids`` (default: all live points);
        noise maps to :data:`NOISE` (-1)."""

    def component_of(self, idx: int) -> int:
        """The point's native component handle — same opacity contract as
        :meth:`label` (only comparable between two live points at one
        instant), but guaranteed to be the backend's *cheapest* point
        query (Euler-tour ROOT / union-find find for the maintained
        engines).  Default: ``label(idx)``."""
        return self.label(idx)

    def core_anchor_of(self, idx: int) -> Optional[int]:
        """The core point ``idx``'s membership rides on: itself if core,
        its anchor core if an attached border point, None if noise.  Only
        backends with ``native_component_queries`` answer this from
        structure; others raise."""
        raise NotImplementedError(
            f"{type(self).__name__} has no native core-anchor query"
        )

    def drain_deltas(
        self,
    ) -> Optional[List[Tuple[int, Optional[int], Optional[int]]]]:
        """Return and clear ``(idx, old, new)`` attachment deltas since the
        previous drain, or None when the backend does not track changes.

        A handle is the point itself (core), its anchor core (attached
        border), or None (noise / not live); the first call activates
        tracking and returns [].  Consumers re-query :meth:`label` for the
        listed ids instead of interpreting the handles globally.
        """
        return None

    @abc.abstractmethod
    def ids(self) -> List[int]:
        """Sorted handles of all live points."""

    @abc.abstractmethod
    def __contains__(self, idx: int) -> bool: ...

    @abc.abstractmethod
    def __len__(self) -> int: ...

    # ---------------------------------------------------------------- #
    # persistence
    # ---------------------------------------------------------------- #
    @abc.abstractmethod
    def _state(self) -> Dict[str, np.ndarray]: ...

    @abc.abstractmethod
    def _load_state(self, state: Dict[str, np.ndarray]) -> None: ...

    def snapshot(self) -> Dict[str, Any]:
        """Serialisable structure state: ``{"config": ..., "state": ...}``
        where every ``state`` value is a fixed-dtype numpy array."""
        return {"config": self.cfg.to_dict(), "state": self._state()}

    def restore(self, snapshot: Dict[str, Any]) -> None:
        """Load a snapshot into this (freshly built, empty) index."""
        cfg = ClusterConfig.from_dict(dict(snapshot["config"]))
        if cfg != self.cfg:
            raise ValueError(
                f"snapshot config {cfg} does not match index config {self.cfg}"
            )
        if len(self):
            raise ValueError("restore() requires an empty index")
        self._load_state(snapshot["state"])

    # ---------------------------------------------------------------- #
    # lifecycle
    # ---------------------------------------------------------------- #
    def close(self) -> None:
        """Release external resources (worker processes, sockets, thread
        pools).  No-op for in-process backends; idempotent.  The index is
        unusable afterwards."""

    def __enter__(self) -> "ClusterIndex":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # ---------------------------------------------------------------- #
    # diagnostics
    # ---------------------------------------------------------------- #
    def check_invariants(self) -> None:
        """Structural self-check; no-op for recompute baselines."""

    def stats(self) -> Dict[str, int]:
        """Backend instrumentation counters (may be empty)."""
        return {}
