"""repro.api — one streaming-first interface over all DBSCAN engines.

    from repro.api import ClusterConfig, build_index, Insert, Delete

    index = build_index(ClusterConfig(d=8, k=10, t=10, eps=0.5,
                                      backend="dynamic"))
    ids = index.insert_batch(X)
    index.apply([Delete(ids[0]), Insert(x_new)])
    index.labels()                      # {idx: label}, noise = -1
    snap = index.snapshot()             # -> restore_index(snap)

Backends are string keys (``available_backends()``); new engines register
with :func:`register_backend`.
"""

from ..core.dynamic_dbscan import NOISE  # noqa: F401
from .config import ClusterConfig  # noqa: F401
from .events import Delete, Insert  # noqa: F401
from .index import ClusterIndex  # noqa: F401
from .registry import (  # noqa: F401
    available_backends,
    build_index,
    register_backend,
    restore_index,
    unregister_backend,
)
from . import backends as _backends  # noqa: F401  (populates the registry)
from .backends import EulerTourIndex, RecomputeIndex  # noqa: F401
# module (not name) import: repro.shard may be mid-initialisation when it
# is what pulled repro.api in; it registers "sharded" when it completes
from .. import shard as _shard  # noqa: F401


def __getattr__(name):  # PEP 562: late-bound re-export
    if name == "ShardedIndex":
        return _shard.ShardedIndex
    raise AttributeError(name)
