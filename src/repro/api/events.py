"""Update events for the unified streaming API.

A workload is a plain iterable of :class:`Insert` / :class:`Delete`
events — the paper's AddPoint / DeletePoint operation set (Alg. 2) as
data, so one harness can drive any backend and mixed streams can be
logged, replayed, and sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Insert:
    """AddPoint(x).  ``idx`` pins an explicit stable handle (must be
    unused); ``None`` lets the index auto-assign the next free one."""

    x: np.ndarray
    idx: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Delete:
    """DeletePoint(idx)."""

    idx: int


Update = object  # Insert | Delete (3.10-friendly alias for annotations)
