"""Built-in backends: every engine in ``repro.core`` behind one interface.

=================  ==================================================
key                engine
=================  ==================================================
``dynamic``        DynamicDBSCAN — the paper's Alg. 2 (exact host keys)
``batched``        BatchedDynamicDBSCAN — batch hashing on host (mixed keys)
``batched-device`` BatchedDynamicDBSCAN(use_device=True) — Pallas/ref kernel
``soa``            SoADynamicDBSCAN — vectorised structure-of-arrays core
``soa-device``     SoADynamicDBSCAN(use_device=True) — bucket_ops kernels
``approx``         SampledCoreDBSCAN — DBSCAN++-style sampled cores
``emz-static``     EMZ recompute-per-query baseline (Esfandiari et al.)
``naive``          exact Algorithm-1 DBSCAN recompute-per-query baseline
``emz-fixed``      EMZFixedCore §5 ablation (insert-only)
=================  ==================================================

The recompute baselines are *lazy*: mutations only touch the point store;
the clustering runs from scratch on the first ``label``/``labels`` query
after a mutation (matching the paper's "recompute after each batch"
protocol when queried once per batch).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.approx import SampledCoreDBSCAN
from ..core.batched import BatchedDynamicDBSCAN
from ..core.dynamic_dbscan import DynamicDBSCAN, claim_index
from ..core.fixed_core import EMZFixedCore
from ..core.hashing import GridLSH
from ..core.soa import SoADynamicDBSCAN
from ..core.static_emz import emz_cluster
from .config import ClusterConfig
from .index import ClusterIndex
from .registry import register_backend

#: backends keyed by the float32 device-hash mixed keys rather than exact
#: int64 grid codes — consumers that must mirror an engine's bucket-key
#: space (shard router, bridge directory, service digests) branch on this
MIXED_KEY_BACKENDS = ("batched", "batched-device", "soa", "soa-device",
                      "approx")


class EulerTourIndex(ClusterIndex):
    """Adapter over the dynamic engines (shared DynamicDBSCAN machinery)."""

    native_component_queries = True

    def __init__(self, cfg: ClusterConfig, engine: DynamicDBSCAN):
        super().__init__(cfg)
        self.engine = engine
        # hand the engine this index's obs handle so structural telemetry
        # (repair depth) lands in the same registry as the adapter's ops
        engine.obs = self.obs
        # bind the native point query directly: the sharded quotient build
        # calls it thousands of times per epoch, so adapter hops count
        self.component_of = engine.get_cluster

    def insert(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        return self.engine.add_point(x, idx=idx)

    def delete(self, idx: int) -> None:
        self.engine.delete_point(idx)

    def insert_batch(self, X, ids=None) -> List[int]:
        X = np.asarray(X, dtype=np.float64)
        if isinstance(self.engine, BatchedDynamicDBSCAN):
            return self.engine.add_batch(X, ids=ids)
        return super().insert_batch(X, ids=ids)

    def label(self, idx: int) -> int:  # hot-path
        return self.engine.get_cluster(idx)

    def labels(self, ids=None) -> Dict[int, int]:
        return self.engine.labels(ids)

    def core_anchor_of(self, idx):
        return self.engine.core_anchor(idx)  # O(1) support/attach lookup

    def drain_deltas(self):
        return self.engine.drain_deltas()

    def is_core(self, idx: int) -> bool:
        return self.engine.is_core(idx)

    def ids(self):
        return sorted(self.engine.points)

    def __contains__(self, idx):
        return idx in self.engine.points

    def __len__(self):
        return len(self.engine.points)

    def _state(self):
        return self.engine.state_dict()

    def _load_state(self, state):
        self.engine.load_state_dict(state)

    def check_invariants(self):
        self.engine.check_invariants()

    def stats(self):
        return {
            "n_repair_scans": self.engine.n_repair_scans,
            "n_repair_links": self.engine.n_repair_links,
            "n_links": self.engine.forest.n_links,
            "n_cuts": self.engine.forest.n_cuts,
        }


class SoAIndex(ClusterIndex):
    """Adapter over :class:`~repro.core.soa.SoADynamicDBSCAN` — the
    vectorised structure-of-arrays engine.  Same protocol surface as
    :class:`EulerTourIndex` (native point queries, O(1) core anchors,
    drain_deltas change feed) with batch mutations as single array
    passes instead of per-point forest updates."""

    native_component_queries = True

    def __init__(self, cfg: ClusterConfig, engine: SoADynamicDBSCAN):
        super().__init__(cfg)
        self.engine = engine
        engine.obs = self.obs
        self.component_of = engine.get_cluster  # bind the native query

    def insert(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        return self.engine.add_point(x, idx=idx)

    def delete(self, idx: int) -> None:
        self.engine.delete_point(idx)

    def insert_batch(self, X, ids=None) -> List[int]:
        return self.engine.add_batch(np.asarray(X, dtype=np.float64),
                                     ids=ids)

    def delete_batch(self, ids) -> None:
        self.engine.delete_batch([int(i) for i in ids])

    def label(self, idx: int) -> int:  # hot-path
        return self.engine.get_cluster(idx)

    def labels(self, ids=None) -> Dict[int, int]:
        return self.engine.labels(ids)

    def core_anchor_of(self, idx):
        return self.engine.core_anchor(idx)

    def drain_deltas(self):
        return self.engine.drain_deltas()

    def is_core(self, idx: int) -> bool:
        return self.engine.is_core(idx)

    def ids(self):
        return sorted(self.engine._row)

    def __contains__(self, idx):
        return idx in self.engine

    def __len__(self):
        return len(self.engine)

    def _state(self):
        return self.engine.state_dict()

    def _load_state(self, state):
        self.engine.load_state_dict(state)

    def check_invariants(self):
        self.engine.check_invariants()

    def stats(self):
        return {
            "n_epoch_rebuilds": self.engine.n_epoch_rebuilds,
            "n_promotions": self.engine.n_promotions,
            "n_demotions": self.engine.n_demotions,
            "n_grab_events": self.engine.n_grab_events,
            "n_scan_events": self.engine.n_scan_events,
        }


class ApproxIndex(SoAIndex):
    """Adapter over :class:`~repro.core.approx.SampledCoreDBSCAN` — same
    protocol surface as :class:`SoAIndex` (it *is* the SoA engine with
    the density test restricted to a deterministic id-hash sample), plus
    sampling diagnostics in ``stats()``."""

    native_component_queries = True

    def stats(self):
        s = super().stats()
        s["sample_rate"] = self.engine.sample_rate
        s["n_sampled"] = self.engine.n_sampled()
        return s


class RecomputeIndex(ClusterIndex):
    """Static-recompute baselines: mutations are O(1) bookkeeping; the
    clustering reruns from scratch on the first query after a mutation."""

    def __init__(self, cfg: ClusterConfig,
                 cluster_fn: Callable[[np.ndarray], np.ndarray]):
        super().__init__(cfg)
        self._cluster_fn = cluster_fn  # (n, d) -> (n,) labels, noise = -1
        self._pts: Dict[int, np.ndarray] = {}
        self._next_idx = 0
        self._cache: Optional[Dict[int, int]] = None

    def insert(self, x, idx=None):
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.cfg.d,):
            raise ValueError(f"point shape {x.shape} != ({self.cfg.d},)")
        idx, self._next_idx = claim_index(self._pts, self._next_idx, idx)
        self._pts[idx] = x
        self._cache = None
        return idx

    def delete(self, idx):
        del self._pts[idx]
        self._cache = None

    def _all_labels(self) -> Dict[int, int]:
        if self._cache is None:
            ids = sorted(self._pts)
            if not ids:
                self._cache = {}
            else:
                lab = self._cluster_fn(np.stack([self._pts[i] for i in ids]))
                self._cache = {i: int(v) for i, v in zip(ids, lab)}
        return self._cache

    def label(self, idx):
        if idx not in self._pts:
            raise KeyError(idx)
        return self._all_labels()[idx]

    def labels(self, ids=None):
        all_lab = self._all_labels()
        if ids is None:
            return dict(all_lab)
        return {i: all_lab[i] for i in ids}

    def ids(self):
        return sorted(self._pts)

    def __contains__(self, idx):
        return idx in self._pts

    def __len__(self):
        return len(self._pts)

    def _state(self):
        ids = sorted(self._pts)
        points = (np.stack([self._pts[i] for i in ids])
                  if ids else np.zeros((0, self.cfg.d)))
        return {
            "ids": np.asarray(ids, dtype=np.int64),
            "points": points.astype(np.float64),
            "next_idx": np.asarray(self._next_idx, dtype=np.int64),
        }

    def _load_state(self, state):
        for i, x in zip(state["ids"], np.asarray(state["points"], np.float64)):
            self._pts[int(i)] = x
        self._next_idx = int(state["next_idx"])
        self._cache = None


class FixedCoreIndex(ClusterIndex):
    """EMZFixedCore §5 ablation: the first ``insert_batch`` freezes the
    core set; later points only attach to frozen core buckets.  The freeze
    boundary is stream state, so deletions are unsupported.

    The underlying engine is fed *incrementally* (its labels list is
    append-only in insertion order), keeping per-batch cost O(batch) —
    the cost profile Figure 2 measures — and making pinned out-of-order
    handles safe: a handle is just a name for a stream position.
    """

    def __init__(self, cfg: ClusterConfig):
        super().__init__(cfg)
        self.engine = EMZFixedCore(cfg.d, cfg.k, cfg.t, cfg.eps,
                                   seed=cfg.seed)
        self._order: List[int] = []  # handles in insertion (stream) order
        self._pts: Dict[int, np.ndarray] = {}
        self._next_idx = 0
        self._n_init = 0  # points in the frozen first batch (0 = not frozen)

    def insert(self, x, idx=None):
        return self.insert_batch(np.asarray(x, dtype=np.float64)[None],
                                 ids=[idx])[0]

    def insert_batch(self, X, ids=None):
        X = np.asarray(X, dtype=np.float64)
        if X.ndim != 2 or X.shape[1] != self.cfg.d:
            raise ValueError(f"batch shape {X.shape} != (n, {self.cfg.d})")
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError("ids length must match batch size")
        out = []
        for j in range(X.shape[0]):
            idx, self._next_idx = claim_index(
                self._pts, self._next_idx,
                ids[j] if ids is not None else None,
            )
            self._pts[idx] = X[j]
            self._order.append(idx)
            out.append(idx)
        self.engine.add_batch(X)
        if self._n_init == 0:
            self._n_init = len(self._order)
        return out

    def delete(self, idx):
        raise NotImplementedError("emz-fixed is insert-only (frozen cores)")

    def _all_labels(self) -> Dict[int, int]:
        return {i: int(v) for i, v in zip(self._order, self.engine._labels)}

    def label(self, idx):
        if idx not in self._pts:
            raise KeyError(idx)
        return self._all_labels()[idx]

    def labels(self, ids=None):
        all_lab = self._all_labels()
        if ids is None:
            return all_lab
        return {i: all_lab[i] for i in ids}

    def ids(self):
        return sorted(self._pts)

    def __contains__(self, idx):
        return idx in self._pts

    def __len__(self):
        return len(self._pts)

    def _state(self):
        # ids in INSERTION order: the engine's labels/freeze boundary are
        # stream state, so restore must replay the original order
        points = (np.stack([self._pts[i] for i in self._order])
                  if self._order else np.zeros((0, self.cfg.d)))
        return {
            "ids": np.asarray(self._order, dtype=np.int64),
            "points": points.astype(np.float64),
            "next_idx": np.asarray(self._next_idx, dtype=np.int64),
            "n_init": np.asarray(self._n_init, dtype=np.int64),
        }

    def _load_state(self, state):
        X = np.asarray(state["points"], dtype=np.float64)
        n_init = int(state["n_init"])
        order = [int(i) for i in state["ids"]]
        if order:
            self.insert_batch(X[:n_init], ids=order[:n_init])
            if len(order) > n_init:
                self.insert_batch(X[n_init:], ids=order[n_init:])
        self._next_idx = int(state["next_idx"])


# -------------------------------------------------------------------- #
# registrations
# -------------------------------------------------------------------- #
def _dynamic_engine(cfg: ClusterConfig, cls, **extra) -> EulerTourIndex:
    return EulerTourIndex(cfg, cls(
        cfg.d, cfg.k, cfg.t, cfg.eps, seed=cfg.seed,
        attach_orphans=cfg.attach_orphans, repair=cfg.repair, **extra,
    ))


@register_backend("dynamic")
def _build_dynamic(cfg: ClusterConfig) -> ClusterIndex:
    return _dynamic_engine(cfg, DynamicDBSCAN)


@register_backend("batched")
def _build_batched(cfg: ClusterConfig) -> ClusterIndex:
    return _dynamic_engine(cfg, BatchedDynamicDBSCAN, use_device=False)


@register_backend("batched-device")
def _build_batched_device(cfg: ClusterConfig) -> ClusterIndex:
    # device hashing through repro.kernels.ops (Pallas on TPU, jnp ref on
    # CPU — selected by REPRO_KERNELS, see kernels/ops.py)
    return _dynamic_engine(cfg, BatchedDynamicDBSCAN, use_device=True)


@register_backend("soa")
def _build_soa(cfg: ClusterConfig) -> ClusterIndex:
    return SoAIndex(cfg, SoADynamicDBSCAN(
        cfg.d, cfg.k, cfg.t, cfg.eps, seed=cfg.seed,
        attach_orphans=cfg.attach_orphans, repair=cfg.repair,
        use_device=False))


@register_backend("soa-device")
def _build_soa_device(cfg: ClusterConfig) -> ClusterIndex:
    # bucket/support/core passes through repro.kernels.ops (Pallas on
    # TPU, jnp ref on CPU — selected by REPRO_KERNELS, see kernels/ops.py)
    return SoAIndex(cfg, SoADynamicDBSCAN(
        cfg.d, cfg.k, cfg.t, cfg.eps, seed=cfg.seed,
        attach_orphans=cfg.attach_orphans, repair=cfg.repair,
        use_device=True))


@register_backend("approx")
def _build_approx(cfg: ClusterConfig) -> ClusterIndex:
    return ApproxIndex(cfg, SampledCoreDBSCAN(
        cfg.d, cfg.k, cfg.t, cfg.eps, seed=cfg.seed,
        attach_orphans=cfg.attach_orphans, repair=cfg.repair,
        use_device=False, sample_rate=cfg.sample_rate,
        approx_seed=cfg.approx_seed))


@register_backend("tiered")
def _build_tiered(cfg: ClusterConfig) -> ClusterIndex:
    from ..tiered import TieredIndex  # lazy: repro.tiered imports repro.api

    return TieredIndex(cfg)


@register_backend("emz-static")
def _build_emz(cfg: ClusterConfig) -> ClusterIndex:
    lsh = GridLSH(cfg.d, cfg.eps, cfg.t, seed=cfg.seed)
    return RecomputeIndex(
        cfg, lambda X: emz_cluster(X, cfg.k, cfg.eps, cfg.t, lsh=lsh)
    )


@register_backend("naive")
def _build_naive(cfg: ClusterConfig) -> ClusterIndex:
    from ..core.naive_dbscan import dbscan  # needs scipy; import lazily

    return RecomputeIndex(cfg, lambda X: dbscan(X, cfg.k, cfg.eps))


@register_backend("emz-fixed")
def _build_emz_fixed(cfg: ClusterConfig) -> ClusterIndex:
    return FixedCoreIndex(cfg)
