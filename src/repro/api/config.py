"""Frozen configuration shared by every clustering backend.

One ``ClusterConfig`` fully determines an index: the LSH family is seeded
from ``(d, eps, t, seed)``, so two indices built from equal configs are
semantically interchangeable — the basis of the backend-equivalence tests
and of snapshot portability.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    d: int                       # point dimensionality
    k: int                       # Definition-4 core threshold
    t: int                       # number of LSH tables
    eps: float                   # grid cell scale (2·eps cells)
    seed: int = 0                # LSH family + sequence-backend seed
    backend: str = "dynamic"     # registry key, see repro.api.backends
    repair: str = "exact"        # 'exact' (Thm-2 fix) | 'paper' (Alg. 2)
    attach_orphans: bool = True  # DESIGN.md §3.2 border re-attachment
    shards: int = 1              # backend="sharded": number of key ranges
    inner_backend: str = "dynamic"  # backend="sharded": per-shard engine
    workers: int = 0             # backend="sharded": thread pool size for
    #                              per-shard fan-out (0/1 = serial)
    incremental_merge: bool = True  # backend="sharded": maintain the
    #                              cross-shard union-find under updates
    #                              (False = rebuild per query, PR-2 path)
    transport: str = "local"     # backend="sharded": how the coordinator
    #                              reaches its shards — "local" (in-process,
    #                              zero-copy), "process" (one spawned
    #                              server process per shard, wire protocol
    #                              over a socketpair; GIL-free update
    #                              fan-out) or "tcp" (same protocol over a
    #                              stream socket with timeouts, retries and
    #                              auth — reconnectable, cross-host capable)
    replicas: int = 0            # backend="sharded": replicas per shard
    #                              lane, fed by deterministic update
    #                              replay; on a dead primary the
    #                              coordinator promotes a replica instead
    #                              of erroring (0 = no fault tolerance)
    rpc_timeout_s: float = 30.0  # wire transports: per-request deadline —
    #                              a request that gets no response within
    #                              this window fails (and, on "tcp",
    #                              retries) instead of hanging forever
    obs: bool = False            # observability: metrics registry + trace
    #                              spans (repro.obs).  Off by default; the
    #                              null instruments keep un-instrumented
    #                              runs and wire bytes bit-identical.
    sample_rate: float = 1.0     # backend="approx": fraction of points in
    #                              the deterministic core sample (1.0 =
    #                              exact; see repro.core.approx)
    approx_seed: int = 0         # backend="approx": seed folded into the
    #                              id-hash sampling predicate

    def __post_init__(self) -> None:
        # Validate at construction with named messages instead of failing
        # deep inside GridLSH.__init__ / the engine constructors.
        if self.d < 1:
            raise ValueError(f"d must be >= 1, got {self.d}")
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.t < 1:
            raise ValueError(f"t must be >= 1, got {self.t}")
        if self.eps <= 0:
            raise ValueError(f"eps must be > 0, got {self.eps}")
        if self.repair not in ("exact", "paper"):
            raise ValueError(f"unknown repair mode {self.repair!r}")
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.workers < 0:
            raise ValueError(f"workers must be >= 0, got {self.workers}")
        if self.replicas < 0:
            raise ValueError(f"replicas must be >= 0, got {self.replicas}")
        if self.rpc_timeout_s <= 0:
            raise ValueError(
                f"rpc_timeout_s must be > 0, got {self.rpc_timeout_s}")
        if self.inner_backend == "sharded":
            raise ValueError("inner_backend cannot itself be 'sharded'")
        if not 0.0 < self.sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate must be in (0, 1], got {self.sample_rate}")
        if self.transport not in ("local", "process", "tcp"):
            raise ValueError(
                f"unknown transport {self.transport!r} "
                "(expected 'local', 'process' or 'tcp')"
            )

    def replace(self, **changes: Any) -> "ClusterConfig":
        return dataclasses.replace(self, **changes)

    def with_shards(self, shards: int,
                    inner: Optional[str] = None) -> "ClusterConfig":
        """Resolve a shard-count request against this config — the one
        definition of the '--shards S' CLI convention.

        ``shards > 1`` wraps this config's backend into ``sharded`` with
        the current backend (or ``inner``) as the per-shard engine; an
        already-``sharded`` config just updates its shard count;
        ``shards <= 1`` on an unsharded config is a no-op.
        """
        if self.backend == "sharded":
            return self.replace(shards=max(1, shards),
                                **({"inner_backend": inner} if inner else {}))
        if shards and shards > 1:
            return self.replace(backend="sharded", shards=shards,
                                inner_backend=inner or self.backend)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ClusterConfig":
        return cls(**d)
