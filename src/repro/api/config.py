"""Frozen configuration shared by every clustering backend.

One ``ClusterConfig`` fully determines an index: the LSH family is seeded
from ``(d, eps, t, seed)``, so two indices built from equal configs are
semantically interchangeable — the basis of the backend-equivalence tests
and of snapshot portability.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    d: int                       # point dimensionality
    k: int                       # Definition-4 core threshold
    t: int                       # number of LSH tables
    eps: float                   # grid cell scale (2·eps cells)
    seed: int = 0                # LSH family + sequence-backend seed
    backend: str = "dynamic"     # registry key, see repro.api.backends
    repair: str = "exact"        # 'exact' (Thm-2 fix) | 'paper' (Alg. 2)
    attach_orphans: bool = True  # DESIGN.md §3.2 border re-attachment

    def __post_init__(self):
        if self.d <= 0:
            raise ValueError(f"d must be positive, got {self.d}")
        if self.k < 1 or self.t < 1:
            raise ValueError(f"k and t must be >= 1, got k={self.k} t={self.t}")
        if self.eps <= 0:
            raise ValueError(f"eps must be positive, got {self.eps}")
        if self.repair not in ("exact", "paper"):
            raise ValueError(f"unknown repair mode {self.repair!r}")

    def replace(self, **changes: Any) -> "ClusterConfig":
        return dataclasses.replace(self, **changes)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ClusterConfig":
        return cls(**d)
