"""Pallas TPU kernels: bucket occupancy / support / core detection.

The SoA engine (``repro.core.soa``) keys every point to ``t`` bucket
*slots* (dense int32 ids resolved on the host against the bucket
directory).  Given those slots, the per-batch inner loops of Definition 4
are pure array passes:

  * ``slot_counts``     — histogram a batch's (n, t) slot matrix into
                          per-slot occupancy deltas (one scatter-add);
  * ``bucket_core_stats`` — gather each point's t bucket sizes and reduce
                          them to ``support = #{i : |bucket_i| >= k}`` and
                          the core flag ``support > 0`` (Definition 4).

Both are bandwidth-bound integer passes like ``lsh_hash``: one VMEM tile
of slots per grid step, with the (padded) size/count vector replicated to
every step.  ``slot_counts`` accumulates across grid steps into a single
output block — TPU grids are sequential, so the += pattern is the
documented reduction idiom.  ``interpret=True`` runs the same kernels on
CPU; the jnp oracles live in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(slots_ref, sizes_ref, supp_ref, core_ref, *, k: int):
    slots = slots_ref[...]          # (bn, t) i32 slot ids
    sizes = sizes_ref[...]          # (nb,) i32 bucket occupancies
    occ = jnp.take(sizes, slots, axis=0)          # (bn, t) gather
    supp = jnp.sum((occ >= k).astype(jnp.int32), axis=-1)
    supp_ref[...] = supp
    core_ref[...] = (supp > 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def bucket_core_stats(
    slots: jnp.ndarray,
    sizes: jnp.ndarray,
    *,
    k: int,
    block_n: int = 256,
    interpret: bool = True,
):
    """(n, t) i32 slots + (nb,) i32 sizes -> ((n,), (n,)) i32 support/core.

    ``support[p] = #{i : sizes[slots[p, i]] >= k}``; ``core = support > 0``.
    See ref.bucket_core_stats.
    """
    n, t = slots.shape
    n_pad = -n % block_n
    if n_pad:
        slots = jnp.pad(slots, ((0, n_pad), (0, 0)))  # pad rows gather slot 0
    nb = sizes.shape[0]
    nb_pad = -nb % 128
    if nb_pad:
        sizes = jnp.pad(sizes, (0, nb_pad))
    grid = ((n + n_pad) // block_n,)
    supp, core = pl.pallas_call(
        functools.partial(_stats_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, t), lambda i: (i, 0)),
            pl.BlockSpec((nb + nb_pad,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n + n_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(slots.astype(jnp.int32), sizes.astype(jnp.int32))
    return supp[:n], core[:n]


def _counts_kernel(slots_ref, out_ref, *, n_slots: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    flat = slots_ref[...].reshape(-1)
    # padded rows carry slot id n_slots (out of bounds) and are dropped
    out_ref[...] += jnp.zeros((n_slots,), jnp.int32).at[flat].add(
        1, mode="drop")


@functools.partial(jax.jit, static_argnames=("n_slots", "block_n", "interpret"))
def slot_counts(
    slots: jnp.ndarray,
    *,
    n_slots: int,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, t) i32 slots -> (n_slots,) i32 occupancy histogram.

    ``out[s] = #{(p, i) : slots[p, i] == s}`` — the per-batch bucket-size
    delta.  See ref.slot_counts.
    """
    n, t = slots.shape
    n_pad = -n % block_n
    nb_pad = -n_slots % 128
    if n_pad:
        # pad with an out-of-range slot so the scatter drops those rows
        slots = jnp.pad(slots, ((0, n_pad), (0, 0)),
                        constant_values=n_slots + nb_pad)
    grid = ((n + n_pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_counts_kernel, n_slots=n_slots + nb_pad),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, t), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((n_slots + nb_pad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_slots + nb_pad,), jnp.int32),
        interpret=interpret,
    )(slots.astype(jnp.int32))
    return out[:n_slots]
