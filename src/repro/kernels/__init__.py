# Pallas TPU kernels for the compute hot-spots, each with a jit'd wrapper
# (ops.py) and a pure-jnp oracle (ref.py):
#   lsh_hash        - grid-LSH bucket keys (the paper's per-update hashing)
#   pairwise_dist   - eps-neighbour counting (exact-DBSCAN baseline)
#   flash_attention - blocked online-softmax attention (LM substrate)
# Public API: repro.kernels.ops (impl dispatch: 'ref' | 'pallas' |
# 'pallas_interpret'); submodules are importable directly.
from . import ops, ref  # noqa: F401
