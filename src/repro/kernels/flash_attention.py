"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Supports causal masking, sliding windows (Gemma-3 local layers) and GQA
(kv head index = q head index // group).  Grid = (batch·q_heads, q blocks,
kv blocks) with the kv dimension innermost so the (block_q, head_dim)
accumulator and the running (m, l) statistics stay resident in VMEM scratch
across a full kv sweep.

Block sizes default to (128, 128): the (128, dh)·(dh, 128) products keep
the MXU at full occupancy for dh >= 128, and a block working set of
q + k + v + acc ≈ 4 · 128 · dh · 4B ≈ 256 KiB (dh=128) fits VMEM with room
for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; interpret mode simulates them on CPU
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)  # noqa: E731
except Exception:  # pragma: no cover
    _SCRATCH = lambda shape: pl.MemorySpace.ANY  # type: ignore  # noqa: E731

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window, block_q: int, block_k: int,
    q_offset: int, kv_len: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    ) + q_offset
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = k_pos < kv_len  # padding
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window

    # skip fully-masked blocks cheaply (still traced; predicated on TPU)
    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)  # (bk, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "scale",
        "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """GQA flash attention. q: (b, hq, sq, dh); k,v: (b, hkv, skv, dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pq = -sq % block_q
    pk = -skv % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    # flatten (b, h) into one grid axis
    qf = qp.reshape(b * hq, sq + pq, dh)
    kf = kp.reshape(b * hkv, skv + pk, dh)
    vf = vp.reshape(b * hkv, skv + pk, dh)

    grid = (b * hq, (sq + pq) // block_q, (skv + pk) // block_k)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            scale=scale,
            causal=causal,
            window=window,
            block_q=block_q,
            block_k=block_k,
            q_offset=q_offset,
            kv_len=skv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec(
                (1, block_k, dh), lambda h, i, j, g=group: (h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, block_k, dh), lambda h, i, j, g=group: (h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq + pq, dh), q.dtype),
        scratch_shapes=[
            _SCRATCH((block_q, dh)),
            _SCRATCH((block_q,)),
            _SCRATCH((block_q,)),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq + pq, dh)[:, :, :sq]
