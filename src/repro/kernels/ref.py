"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose (bit-exact for the integer hash) against these.
They are also the *portable* implementations used when lowering for
backends where the Mosaic TPU kernels are unavailable (e.g. the CPU
dry-run).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# murmur3-style finalizer constants (int32 wrap-around arithmetic);
# plain Python ints so Pallas kernels don't capture traced constants
MIX_A = -1975444243  # 0x85EBCA6D as int32
MIX_B = -1029739211  # 0xC2B2AE35 as int32


def _avalanche(h: jnp.ndarray) -> jnp.ndarray:
    h = h ^ jax.lax.shift_right_logical(h, 16)
    h = h * MIX_A
    h = h ^ jax.lax.shift_right_logical(h, 13)
    h = h * MIX_B
    h = h ^ jax.lax.shift_right_logical(h, 16)
    return h


def lsh_hash(x: jnp.ndarray, eta: jnp.ndarray, mixers: jnp.ndarray,
             inv_cell: float) -> jnp.ndarray:
    """Grid-LSH bucket keys.

    x:      (n, d) float32 points
    eta:    (t,)   float32 per-table offsets (the paper's eta * 1_d)
    mixers: (2, t, d) int32 odd multipliers (two independent families)
    returns (n, t, 2) int32 keys; two points share a bucket in table i iff
    their grid-code vectors match — keys collide spuriously w.p. ~2^-64.
    """
    codes = jnp.floor(
        (x[:, None, :] + eta[None, :, None]) * jnp.float32(inv_cell)
    ).astype(jnp.int32)  # (n, t, d)
    # (n, t, d) * (t, d) summed over d, int32 wrap-around
    acc_a = jnp.sum(codes * mixers[0][None], axis=-1, dtype=jnp.int32)
    acc_b = jnp.sum(codes * mixers[1][None], axis=-1, dtype=jnp.int32)
    return jnp.stack([_avalanche(acc_a), _avalanche(acc_b)], axis=-1)


def bucket_core_stats(slots: jnp.ndarray, sizes: jnp.ndarray, k: int):
    """Definition-4 support counts from bucket occupancies.

    slots: (n, t) int32 bucket-slot ids (host-resolved directory entries)
    sizes: (nb,) int32 current occupancy per slot
    returns (support, core): (n,) int32 ``#{i : sizes[slots[p,i]] >= k}``
    and the core indicator ``support > 0``.
    """
    occ = jnp.take(sizes, slots, axis=0)
    supp = jnp.sum((occ >= k).astype(jnp.int32), axis=-1)
    return supp, (supp > 0).astype(jnp.int32)


def slot_counts(slots: jnp.ndarray, n_slots: int) -> jnp.ndarray:
    """Occupancy histogram of a batch's (n, t) slot matrix:
    ``out[s] = #{(p, i) : slots[p, i] == s}`` — the bucket-size delta one
    insert batch contributes."""
    flat = slots.reshape(-1)
    return jnp.zeros((n_slots,), jnp.int32).at[flat].add(1, mode="drop")


def eps_neighbor_counts(x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """|B(x_i, eps)| per point (self included), O(n^2 d)."""
    sq = jnp.sum(x * x, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return jnp.sum(d2 <= eps * eps + 1e-6, axis=-1).astype(jnp.int32)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
) -> jnp.ndarray:
    """Reference GQA attention.

    q: (b, hq, sq, dh); k, v: (b, hkv, skv, dh) with hq % hkv == 0.
    ``q_offset``: absolute position of q[0] (for decode: skv - sq).
    ``window``: sliding-window size (keys with q_pos - k_pos >= window are
    masked); None = full.
    """
    b, hq, sq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    q_pos = jnp.arange(sq)[:, None] + q_offset
    k_pos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)
