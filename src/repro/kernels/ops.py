"""Public jit'd wrappers for the Pallas kernels.

Each op dispatches between the Mosaic TPU kernel and the pure-jnp reference
(``ref.py``).  The dry-run lowers on a CPU backend where Mosaic kernels are
unavailable, so ``impl='ref'`` is the default there; on real TPU hardware
pass ``impl='pallas'`` (or set ``REPRO_KERNELS=pallas``).
"""

from __future__ import annotations

import os


from . import bucket_ops as _bo
from . import flash_attention as _fa
from . import lsh_hash as _lh
from . import pairwise_dist as _pd
from . import ref as _ref


def _impl(impl: str | None) -> str:
    if impl is None:
        impl = os.environ.get("REPRO_KERNELS", "ref")
    if impl not in ("ref", "pallas", "pallas_interpret"):
        raise ValueError(impl)
    return impl


def lsh_hash(x, eta, mixers, *, inv_cell: float, impl: str | None = None):
    impl = _impl(impl)
    if impl == "ref":
        return _ref.lsh_hash(x, eta, mixers, inv_cell)
    return _lh.lsh_hash(
        x, eta, mixers, inv_cell=inv_cell, interpret=impl == "pallas_interpret"
    )


def bucket_core_stats(slots, sizes, *, k: int, impl: str | None = None):
    impl = _impl(impl)
    if impl == "ref":
        return _ref.bucket_core_stats(slots, sizes, k)
    return _bo.bucket_core_stats(
        slots, sizes, k=k, interpret=impl == "pallas_interpret"
    )


def slot_counts(slots, *, n_slots: int, impl: str | None = None):
    impl = _impl(impl)
    if impl == "ref":
        return _ref.slot_counts(slots, n_slots)
    return _bo.slot_counts(
        slots, n_slots=n_slots, interpret=impl == "pallas_interpret"
    )


def eps_neighbor_counts(x, *, eps: float, impl: str | None = None):
    impl = _impl(impl)
    if impl == "ref":
        return _ref.eps_neighbor_counts(x, eps)
    return _pd.eps_neighbor_counts(
        x, eps=eps, interpret=impl == "pallas_interpret"
    )


def attention(
    q, k, v, *, causal=True, window=None, q_offset=0, scale=None,
    impl: str | None = None, block_q: int = 128, block_k: int = 128,
):
    impl = _impl(impl)
    if impl == "ref":
        return _ref.attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale
        )
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, scale=scale,
        block_q=block_q, block_k=block_k,
        interpret=impl == "pallas_interpret",
    )
