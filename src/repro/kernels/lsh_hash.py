"""Pallas TPU kernel: grid-LSH bucket keys for a batch of points.

The paper's per-update hashing cost is O(t·d); for streaming batches this
is an embarrassingly parallel, bandwidth-bound pass over (n, d) — the
natural TPU mapping is one VMEM tile of points per grid step, all t tables
computed in-register, and only the (n, t, 2) int32 keys returned to the
host (the Euler-tour structure consumes keys, never coordinates).

Tiling: X is tiled (block_n, d) in VMEM; eta (t,) and the two mixer
matrices (2, t, d) are small and replicated to every grid step.  The MXU is
not used (integer work); the VPU does floor/mul/add; arithmetic intensity
is ~t ops/byte, so the kernel is HBM-bound by design — the roofline target
is a single straming pass at memory bandwidth.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MIX_A, MIX_B


def _kernel(x_ref, eta_ref, mix_ref, out_ref, *, inv_cell: float, t: int):
    x = x_ref[...]  # (bn, d) f32
    eta = eta_ref[...]  # (t,) f32
    mix = mix_ref[...]  # (2, t, d) i32
    codes = jnp.floor(
        (x[:, None, :] + eta[None, :, None]) * jnp.float32(inv_cell)
    ).astype(jnp.int32)  # (bn, t, d)
    acc_a = jnp.sum(codes * mix[0][None], axis=-1, dtype=jnp.int32)
    acc_b = jnp.sum(codes * mix[1][None], axis=-1, dtype=jnp.int32)

    def _avalanche(h):
        h = h ^ jax.lax.shift_right_logical(h, 16)
        h = h * MIX_A
        h = h ^ jax.lax.shift_right_logical(h, 13)
        h = h * MIX_B
        h = h ^ jax.lax.shift_right_logical(h, 16)
        return h

    out_ref[...] = jnp.stack([_avalanche(acc_a), _avalanche(acc_b)], axis=-1)


@functools.partial(jax.jit, static_argnames=("inv_cell", "block_n", "interpret"))
def lsh_hash(
    x: jnp.ndarray,
    eta: jnp.ndarray,
    mixers: jnp.ndarray,
    *,
    inv_cell: float,
    block_n: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, d) f32 -> (n, t, 2) int32 bucket keys. See ref.lsh_hash."""
    n, d = x.shape
    t = eta.shape[0]
    n_pad = -n % block_n
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, inv_cell=inv_cell, t=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((2, t, d), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, t, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, t, 2), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.float32), eta.astype(jnp.float32), mixers.astype(jnp.int32))
    return out[:n]
