"""Pallas TPU kernel: blocked eps-neighbour counting (exact DBSCAN core).

The O(n² d) hot spot of Algorithm 1.  Squared distances are computed in the
MXU-friendly form ‖x‖² + ‖y‖² − 2·x·yᵀ with (block_m × d)·(d × block_n)
tiles; the per-row neighbour counts accumulate across the column-block grid
dimension (innermost), so each output tile stays resident in VMEM for a
whole row sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xm_ref, xn_ref, nvalid_ref, out_ref, *, eps2: float, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xm = xm_ref[...]  # (bm, d)
    xn = xn_ref[...]  # (bn, d)
    sm = jnp.sum(xm * xm, axis=-1)
    sn = jnp.sum(xn * xn, axis=-1)
    dots = jax.lax.dot_general(
        xm, xn, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = sm[:, None] + sn[None, :] - 2.0 * dots
    # mask out padding columns (global column index >= n_valid)
    col = j * block_n + jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    ok = (d2 <= eps2) & (col < nvalid_ref[0])
    out_ref[...] += jnp.sum(ok, axis=-1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_m", "block_n", "interpret")
)
def eps_neighbor_counts(
    x: jnp.ndarray,
    *,
    eps: float,
    block_m: int = 256,
    block_n: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """(n, d) -> (n,) int32 counts of points within eps (self included)."""
    n, d = x.shape
    pm = -n % block_m
    pn = -n % block_n
    xp = jnp.pad(x.astype(jnp.float32), ((0, max(pm, pn)), (0, 0)))
    xm = xp[: n + pm]
    xn = xp[: n + pn]
    grid = ((n + pm) // block_m, (n + pn) // block_n)
    nvalid = jnp.array([n], dtype=jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel, eps2=eps * eps + 1e-6, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pm,), jnp.int32),
        interpret=interpret,
    )(xm, xn, nvalid)
    return out[:n]
