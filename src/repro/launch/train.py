"""End-to-end training driver.

Wires every substrate together: config -> mesh -> model -> sharded
params/optimizer -> curated data pipeline -> train loop with heartbeats,
straggler tracking, async checkpointing and checkpoint-restart.

On this CPU container it trains reduced configs for real (see
examples/train_lm.py for the ~100M-param run); on a TPU fleet the same
driver runs the full configs — the mesh/sharding/launch layers are
identical (the dry-run proves they compile at 512 chips).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch granite-20b --smoke \
      --steps 50 --curation balance
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import ARCH_IDS, get_config
from ..data.pipeline import CurationFilter, Pipeline, SyntheticTokenStream
from ..models.registry import build_model
from ..optim import AdamW, warmup_cosine
from ..runtime import HeartbeatRegistry, StragglerDetector
from ..training import make_train_step
from .mesh import make_host_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--curation", default="off",
                    choices=["off", "balance", "dedup", "novelty"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--d-model-override", type=int, default=0)
    ap.add_argument("--preset", default=None, choices=[None, "100m"],
                    help="'100m': a ~124M-param granite-family config "
                         "(12L x 768, vocab 32k) for real-hardware runs")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "100m":
        cfg = dataclasses.replace(
            cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000, grad_accum=1,
        )
    elif args.smoke:
        cfg = cfg.smoke()
    if args.d_model_override:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model_override,
            head_dim=args.d_model_override // max(cfg.n_heads, 1) or None,
        )
    model = build_model(cfg)
    mesh = make_host_mesh()
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    print(f"arch={cfg.name} params≈{cfg.n_params()/1e6:.1f}M "
          f"mesh={mesh_shape}")

    params, axes = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(args.lr, 20, max(args.steps, 100)))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, mesh=mesh,
                                      grad_accum=args.grad_accum))

    # data
    src = SyntheticTokenStream(cfg.vocab_size, args.seq, args.batch, seed=1)
    curation = None
    if args.curation != "off":
        curation = CurationFilter(d=src.embed_dim, k=8, t=8, eps=0.6,
                                  policy=args.curation, window=20_000)
    pipe = Pipeline(iter(src), curation=curation)

    # runtime services (single-host simulation of the fleet services)
    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name, keep_n=2)
    hb = HeartbeatRegistry(n_hosts=1, timeout_s=300)
    sd = StragglerDetector(n_hosts=1)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    losses = []
    with mesh:
        for step in range(start, args.steps):
            batch = next(pipe)
            t0 = time.time()
            jb = {k: jnp.asarray(v) for k, v in batch.items()
                  if k in ("tokens", "labels", "frames", "patches")}
            params, opt_state, metrics = step_fn(params, opt_state, jb)
            dt = time.time() - t0
            hb.beat(0, step)
            sd.record(0, dt)
            losses.append(float(metrics["loss"]))
            if step % 5 == 0 or step == args.steps - 1:
                kept = (f" kept={curation.n_kept}/{curation.n_seen}"
                        if curation else "")
                print(f"step {step:4d} loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"dt={dt*1e3:.0f}ms{kept}")
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
    ckpt.wait()
    pipe.close()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
