"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips, axes
(data, model).  Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) —
the ``pod`` axis is pure data parallelism across ICI-disjoint pods (DCN).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2, data: int = 2, pod: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    want = model * data * pod
    if want > n:
        model = data = pod = 1
        model = min(2, n)
        data = n // model
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
