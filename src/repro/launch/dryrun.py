import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input shape) cell on the
production meshes — single-pod 16×16 (data, model) and multi-pod 2×16×16
(pod, data, model) — and records memory analysis, cost analysis and the
HLO-derived roofline terms to JSON (read by EXPERIMENTS.md §Dry-run and
benchmarks/roofline.py).

The XLA_FLAGS line above MUST run before any jax import (jax locks the
device count at first init); nothing else in the repo sets this flag.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCH_IDS, SHAPES, cell_supported, get_config
from .cells import build_cell
from .hlo_analysis import analyze_compiled
from .mesh import make_production_mesh

RESULTS = Path(__file__).resolve().parents[3] / "results"


def run_cell(arch: str, shape: str, multi_pod: bool,
             save_hlo: bool = False, grad_accum=None, sp: bool = False) -> dict:
    import dataclasses as _dc

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {
        "arch": arch + ("+sp" if sp else ""), "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    ok, why = cell_supported(arch, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    try:
        cfg = None
        if sp:
            cfg = _dc.replace(get_config(arch), seq_shard_activations=True)
        cell = build_cell(arch, shape, mesh, grad_accum=grad_accum, cfg=cfg)
        lowered = cell.lower()
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["status"] = "ok"
        rec["lower_s"] = round(t1 - t0, 1)
        rec["compile_s"] = round(t2 - t1, 1)
        rec.update(analyze_compiled(compiled))
        mem = compiled.memory_analysis()
        print(f"[{arch} × {shape} × {rec['mesh']}] compiled in "
              f"{rec['compile_s']}s")
        print("  memory_analysis:", mem)
        ca = compiled.cost_analysis()
        print("  cost_analysis flops (one loop iter, see hlo_analysis):",
              ca.get("flops"))
        print(f"  flops/device={rec['flops_per_device']:.3e} "
              f"hbm_bytes/device={rec['hbm_bytes_per_device']:.3e} "
              f"collective_bytes/device={rec['collective_bytes_per_device']:.3e}")
        if save_hlo:
            RESULTS.mkdir(exist_ok=True)
            (RESULTS / f"hlo_{arch}_{shape}_{rec['mesh']}.txt").write_text(
                compiled.as_text()
            )
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[{arch} × {shape} × {rec['mesh']}] FAILED: {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream variant")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dryrun needs 512 placeholder devices"

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                records.append(
                    run_cell(arch, shape, mp, save_hlo=args.save_hlo,
                             grad_accum=args.grad_accum, sp=args.sp)
                )
    RESULTS.mkdir(exist_ok=True)
    out = Path(args.out) if args.out else RESULTS / "dryrun.json"
    existing = []
    if out.exists():
        existing = json.loads(out.read_text())
        keys = {(r["arch"], r["shape"], r["mesh"]) for r in records}
        existing = [r for r in existing
                    if (r["arch"], r["shape"], r["mesh"]) not in keys]
    out.write_text(json.dumps(existing + records, indent=1))
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_err = sum(r["status"] == "error" for r in records)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors -> {out}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
