"""Optimised-HLO analysis: per-device FLOPs, HBM traffic and collective
bytes with while-loop trip-count multipliers.

``compiled.cost_analysis()`` does NOT multiply while-loop bodies (verified
empirically: a 5-iteration scan reports one iteration's flops), and our
models scan over layers/microbatches, so we parse ``compiled.as_text()``
ourselves:

  * computations are parsed into op lists with a per-computation symbol
    table (operand shapes are resolved by name — HLO prints only result
    types inline);
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}``;
    body metrics are multiplied by the trip count;
  * FLOPs: 2 · |result| · |contracting dims| per ``dot`` (dots dominate all
    our models; elementwise flops are ignored — documented);
  * HBM traffic: Σ (operands + result) over top-level kernels (fusion
    internals excluded — they live in registers/VMEM);
  * collective bytes: per-device result sizes of all-reduce (×2 for the
    ring), all-gather, reduce-scatter (×group), all-to-all,
    collective-permute, scaled by (g-1)/g.

All sizes are PER DEVICE (the HLO is the SPMD-partitioned module).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_CALL_ATTRS = ("calls=", "body=", "to_apply=", "condition=")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symbols: Dict[str, str]  # %name -> type string


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = Computation(m.group(1), [], {})
                    if line.lstrip().startswith("ENTRY"):
                        entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        # operands: up to the matching close paren of the op call
        depth = 1
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = rest[:i], rest[i + 1:]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        op = Op(name, type_str, kind, operands, attrs)
        cur.ops.append(op)
        cur.symbols[name] = type_str
    return comps, entry


def _trip_count(op: Op) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.attrs)
    return int(m.group(1)) if m else 1


def _called(op: Op) -> List[str]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(attr + r"%?([\w.\-]+)", op.attrs):
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if m:
        out += re.findall(r"%?([\w.\-]+)", m.group(1))
    return out


def _group_size(op: Op) -> int:
    # replica_groups=[4,2]<=[8]  -> 4 groups of size 2
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.attrs)
    if m:  # explicit groups: {{0,1},{2,3}}
        return len(m.group(1).split(","))
    return 2


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    _, rdims = _shape_dims(op.type_str)
    out = 1.0
    for d in rdims:
        out *= d
    lhs_type = symbols.get(op.operands[0], "") if op.operands else ""
    _, ldims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contract = 1.0
    if m and ldims:
        for idx in m.group(1).split(","):
            if idx:
                contract *= ldims[int(idx)]
    return 2.0 * out * contract


_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "bitcast", "tuple",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call",
}


@dataclasses.dataclass
class Metrics:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Metrics", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


def analyze(text: str) -> Metrics:
    comps, entry = parse_hlo(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    cache: Dict[Tuple[str, bool], Metrics] = {}

    def comp_metrics(name: str, count_bytes: bool) -> Metrics:
        key = (name, count_bytes)
        if key in cache:
            return cache[key]
        comp = comps.get(name)
        m = Metrics()
        cache[key] = m
        if comp is None:
            return m
        for op in comp.ops:
            if op.kind == "dot":
                m.flops += _dot_flops(op, comp.symbols)
            if op.kind in COLLECTIVES or op.kind.startswith("all-") or \
               op.kind == "collective-permute":
                g = _group_size(op)
                size = _shape_bytes(op.type_str)
                if op.kind == "all-reduce":
                    wire = 2.0 * size * (g - 1) / g
                elif op.kind == "reduce-scatter":
                    wire = size * (g - 1)
                elif op.kind == "all-gather":
                    wire = size * (g - 1) / g
                elif op.kind == "all-to-all":
                    wire = size * (g - 1) / g
                else:  # collective-permute
                    wire = size
                m.collective_bytes += wire
                m.per_collective[op.kind] = m.per_collective.get(op.kind, 0.0) + wire
            if count_bytes and op.kind not in _FREE_OPS:
                b = _shape_bytes(op.type_str)
                for o in op.operands:
                    b += _shape_bytes(comp.symbols.get(o, ""))
                m.hbm_bytes += b
            # recurse
            if op.kind == "while":
                trip = _trip_count(op)
                body_cond = _called(op)
                for child in body_cond:
                    cm = comp_metrics(child, count_bytes)
                    m.add(cm, trip)
            elif op.kind == "conditional":
                for child in _called(op):
                    m.add(comp_metrics(child, count_bytes), 1.0)
            elif op.kind in ("call", "async-start"):
                for child in _called(op):
                    m.add(comp_metrics(child, count_bytes), 1.0)
            elif op.kind == "fusion":
                # flops/collectives from internals; bytes already counted
                for child in _called(op):
                    m.add(comp_metrics(child, False), 1.0)
        return m

    return comp_metrics(entry, True)


def analyze_compiled(compiled) -> Dict[str, float]:
    m = analyze(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        }
    except Exception:
        mem_d = {}
    try:
        ca = compiled.cost_analysis()
        xla_flops = float(ca.get("flops", -1.0))
    except Exception:
        xla_flops = -1.0
    return {
        "flops_per_device": m.flops,
        "hbm_bytes_per_device": m.hbm_bytes,
        "collective_bytes_per_device": m.collective_bytes,
        "per_collective": dict(m.per_collective),
        "xla_cost_flops_unrolled": xla_flops,
        **mem_d,
    }
