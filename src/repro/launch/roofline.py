"""Roofline terms from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (constants given by the brief).

For each (arch × shape × mesh) record from results/dryrun.json:
  T_comp = FLOPs / (chip peak)          [per-device FLOPs from the HLO]
  T_mem  = HBM bytes / (HBM bw)          [per-device, loop-expanded]
  T_coll = collective bytes / (link bw)  [per-device wire bytes]
plus MODEL_FLOPS = 6·N·D (active-N for MoE; decode: D = tokens decoded)
and the usefulness ratio MODEL_FLOPS / (chips × HLO_FLOPs_per_device).

Caveats (documented for honesty):
  * the HBM term is an upper-bound proxy — it counts operands+results of
    every scheduled kernel in the CPU-partitioned HLO; real TPU fusion
    would cut it.  It is consistent across cells and iterations, which is
    what the hillclimb needs.
  * peak FLOP/s assumes bf16 MXU work; f32 reductions run slower, so
    T_comp is optimistic for f32-heavy cells.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

RESULTS = Path(__file__).resolve().parents[3] / "results"


def model_flops(cfg, shape) -> float:
    """6·N·D with active params for MoE; decode steps count 1 token."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def roofline_row(rec: Dict, cfg=None, shape=None) -> Dict:
    chips = rec["chips"]
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    t_mem = rec["hbm_bytes_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_per_device"] / ICI_BW
    dominant = max(
        ("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_comp_s": t_comp, "t_mem_s": t_mem, "t_coll_s": t_coll,
        "dominant": dominant,
        "bound_time_s": max(t_comp, t_mem, t_coll),
        "roofline_fraction": t_comp / max(t_comp, t_mem, t_coll, 1e-30),
        "peak_hbm_gb": rec.get("peak_bytes", 0) / 2**30,
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops"] = mf
        out["useful_ratio"] = mf / max(chips * rec["flops_per_device"], 1e-30)
        out["mfu_upper_bound"] = mf / (
            chips * PEAK_FLOPS * max(t_comp, t_mem, t_coll, 1e-30)
        )
    return out


def build_table(dryrun_json: Optional[Path] = None) -> List[Dict]:
    from ..configs import get_config, get_shape

    path = dryrun_json or (RESULTS / "dryrun.json")
    rows = []
    for rec in json.loads(path.read_text()):
        if rec.get("status") != "ok":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": rec["status"],
                "reason": rec.get("reason", rec.get("error", ""))[:90],
            })
            continue
        cfg = get_config(rec["arch"].split("+")[0])  # variants: "arch+sp"
        shape = get_shape(rec["shape"])
        row = roofline_row(rec, cfg, shape)
        row["status"] = "ok"
        rows.append(row)
    return rows


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24}{'shape':13}{'mesh':9}{'T_comp':>9}{'T_mem':>9}"
           f"{'T_coll':>9}{'bound':>11}{'MFU_ub':>8}{'useful':>8}{'HBM_GB':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(
                f"{r['arch']:24}{r['shape']:13}{r['mesh']:9}"
                f"  [{r['status']}] {r.get('reason','')}"
            )
            continue
        lines.append(
            f"{r['arch']:24}{r['shape']:13}{r['mesh']:9}"
            f"{r['t_comp_s']:9.3f}{r['t_mem_s']:9.3f}{r['t_coll_s']:9.3f}"
            f"{r['dominant']:>11}{r.get('mfu_upper_bound', 0):8.3f}"
            f"{r.get('useful_ratio', 0):8.3f}{r['peak_hbm_gb']:8.1f}"
        )
    return "\n".join(lines)


def main():
    rows = build_table()
    print(format_table(rows))
    (RESULTS / "roofline.json").write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
