"""Serving driver: continuous-batching engine with request clustering.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \
      --requests 16 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import ARCH_IDS, get_config
from ..models.registry import build_model
from ..serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cluster", action="store_true",
                    help="dynamic-DBSCAN request clustering")
    ap.add_argument("--cluster-shards", type=int, default=1,
                    help="shard the request-clustering window across S "
                         "LSH key ranges")
    ap.add_argument("--cluster-transport", default="local",
                    choices=("local", "process", "tcp"),
                    help="how the clustering shards are reached: in-process, "
                         "spawned per-shard server processes, or TCP with "
                         "timeouts/retries/auth")
    ap.add_argument("--cluster-replicas", type=int, default=0,
                    help="replicas per clustering shard (failover instead "
                         "of failure when a shard worker dies)")
    ap.add_argument("--tier", type=float, default=None, metavar="RATE",
                    help="tiered request clustering (repro.tiered): serve "
                         "labels from a sampled-core front tier at this "
                         "sample_rate while the exact tier verifies "
                         "asynchronously")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch=args.batch, kv_len=args.kv_len,
                        cluster_requests=args.cluster, embed_dim=8,
                        cluster_shards=args.cluster_shards,
                        cluster_transport=args.cluster_transport,
                        cluster_replicas=args.cluster_replicas,
                        cluster_tier=args.tier)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab_size, size=int(rng.integers(2, 8))),
            max_new_tokens=args.max_new,
            embedding=rng.normal(size=8) if args.cluster else None,
        ))
    done = eng.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in done.values())
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    for rid in sorted(done)[:4]:
        print(f"  req {rid}: {done[rid].out_tokens}")
    eng.close()
    return done


if __name__ == "__main__":
    main()
