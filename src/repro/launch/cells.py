"""Dry-run cell construction: (arch × shape × mesh) -> lowered/compiled
step with ShapeDtypeStruct inputs and NamedSharding in_shardings.

Everything here is allocation-free: params come from ``jax.eval_shape``
over the model init, caches likewise; only the compiled artifact and its
analyses are materialised.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import ArchConfig, ShapeConfig, get_config, get_shape
from ..models.registry import ModelAPI, build_model
from ..optim import AdamW, warmup_cosine
from ..sharding import logical_to_spec, spec_tree
from ..training import make_train_step

# per-(arch, shape) gradient-accumulation overrides: bounds live activation
# memory so the big configs fit 16 GB/chip (§Perf iterates on these)
ACCUM_OVERRIDES = {
    ("qwen1.5-110b", "train_4k"): 16,
    ("granite-20b", "train_4k"): 8,
    ("gemma3-27b", "train_4k"): 8,
    ("dbrx-132b", "train_4k"): 16,
    ("llava-next-mistral-7b", "train_4k"): 4,
    ("phi3-mini-3.8b", "train_4k"): 4,
    ("hymba-1.5b", "train_4k"): 2,
    ("mamba2-780m", "train_4k"): 2,
    ("granite-moe-1b-a400m", "train_4k"): 2,
    ("whisper-small", "train_4k"): 2,
}


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _tree_shardings(mesh, axes_tree, shape_tree):
    specs = spec_tree(axes_tree, shape_tree, mesh)
    return jax.tree.map(
        lambda s: _named(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_params(model: ModelAPI) -> Tuple[Any, Any]:
    holder = {}

    def init_params(key):
        p, a = model.init(key)
        holder["axes"] = a
        return p

    shapes = jax.eval_shape(init_params, jax.random.PRNGKey(0))
    return shapes, holder["axes"]


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, with_labels: bool):
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    out: Dict[str, Any] = {}
    ax: Dict[str, Any] = {}
    if cfg.family == "audio":
        s_txt = max(S // 4, 8)
        out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16)
        ax["frames"] = ("batch", None, None)
        out["tokens"] = jax.ShapeDtypeStruct((B, s_txt), i32)
        ax["tokens"] = ("batch", None)
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((B, s_txt), i32)
            ax["labels"] = ("batch", None)
    elif cfg.family == "vlm":
        s_txt = S - cfg.n_patches
        out["tokens"] = jax.ShapeDtypeStruct((B, s_txt), i32)
        ax["tokens"] = ("batch", None)
        out["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_vision), bf16)
        ax["patches"] = ("batch", None, None)
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((B, s_txt), i32)
            ax["labels"] = ("batch", None)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        ax["tokens"] = ("batch", None)
        if with_labels:
            out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            ax["labels"] = ("batch", None)
    return out, ax


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ArchConfig
    step_fn: Any         # jitted
    args: tuple          # ShapeDtypeStructs
    kind: str
    mesh: Any = None

    def lower(self):
        # trace under the mesh context so with_sharding_constraint
        # (shard_activation) resolves logical axes against a live mesh
        with self.mesh:
            return self.step_fn.lower(*self.args)

    def run(self, *args):
        with self.mesh:
            return self.step_fn(*args)


def build_cell(arch_id: str, shape_id: str, mesh: Mesh,
               grad_accum: Optional[int] = None,
               cfg: Optional[ArchConfig] = None,
               shape: Optional[ShapeConfig] = None) -> Cell:
    cfg = cfg if cfg is not None else get_config(arch_id)
    shape = shape if shape is not None else get_shape(shape_id)
    model = build_model(cfg)
    pshapes, paxes = abstract_params(model)
    pshard = _tree_shardings(mesh, paxes, pshapes)
    repl = _named(mesh, P())

    if shape.kind == "train":
        accum = grad_accum or ACCUM_OVERRIDES.get((arch_id, shape_id), cfg.grad_accum)
        # microbatches must stay shardable over the full DP extent
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = sizes.get("pod", 1) * sizes.get("data", 1)
        accum = max(1, min(accum, shape.global_batch // dp_total))
        opt = AdamW(lr=warmup_cosine(3e-4, 100, 10_000))
        ostate = jax.eval_shape(opt.init, pshapes)
        oshard = _tree_shardings(
            mesh, opt.state_axes(paxes),
            {"m": pshapes, "v": pshapes, "step": jax.ShapeDtypeStruct((), jnp.int32)},
        )
        bshapes, baxes = batch_specs(cfg, shape, with_labels=True)
        bshard = {
            k: _named(mesh, logical_to_spec(baxes[k], v.shape, mesh))
            for k, v in bshapes.items()
        }
        step = make_train_step(model, opt, mesh=mesh, grad_accum=accum)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, repl),
            donate_argnums=(0, 1),
        )
        return Cell(arch_id, shape_id, cfg, jitted, (pshapes, ostate, bshapes), "train", mesh)

    if shape.kind == "prefill":
        bshapes, baxes = batch_specs(cfg, shape, with_labels=False)
        bshard = {
            k: _named(mesh, logical_to_spec(baxes[k], v.shape, mesh))
            for k, v in bshapes.items()
        }
        fwd = functools.partial(_prefill_fn, model=model, mesh=mesh)
        jitted = jax.jit(
            fwd,
            in_shardings=(pshard, bshard),
            out_shardings=_named(
                mesh,
                logical_to_spec(
                    ("batch", None, "act_vocab"),
                    (shape.global_batch, 1, cfg.padded_vocab),
                    mesh,
                ),
            ),
        )
        return Cell(arch_id, shape_id, cfg, jitted, (pshapes, bshapes), "prefill", mesh)

    # decode
    B, S = shape.global_batch, shape.seq_len
    holder = {}

    def cache_init():
        c, a = model.decode_init(B, S)
        holder["axes"] = a
        return c

    cshapes = jax.eval_shape(cache_init)
    cshard = _tree_shardings(mesh, holder["axes"], cshapes)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tshard = _named(mesh, logical_to_spec(("batch", None), (B, 1), mesh))
    dstep = functools.partial(_decode_fn, model=model, mesh=mesh)
    jitted = jax.jit(
        dstep,
        in_shardings=(pshard, cshard, tshard, repl),
        out_shardings=(
            _named(mesh, logical_to_spec(("batch", "act_vocab"), (B, cfg.padded_vocab), mesh)),
            cshard,
        ),
        donate_argnums=(1,),
    )
    return Cell(arch_id, shape_id, cfg, jitted, (pshapes, cshapes, token, pos), "decode", mesh)


def _prefill_fn(params, batch, *, model, mesh):
    return model.forward(params, batch, mesh)


def _decode_fn(params, caches, token, pos, *, model, mesh):
    return model.decode_step(params, caches, token, pos, mesh)
