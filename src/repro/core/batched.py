"""Beyond-paper batched update path: device hashing + host structure.

The paper processes a batch of B updates as B sequential O(polylog)
operations, each paying O(t·d) hashing on the host.  On TPU the hashing is
one ``lsh_hash`` kernel call over the whole batch (bandwidth-bound, ~t
ops/byte); only the (B, t, 2) int32 keys come back to the host, which then
performs the pointer updates.  The clustering is identical (H is invariant
to update order and to the key representation — §4.2), the throughput is
not: see benchmarks/kernels.py.

``BatchedDynamicDBSCAN`` shares all the machinery of ``DynamicDBSCAN`` but
keys every bucket by the kernel's mixed keys, so single-point and batch
updates interoperate.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from .dynamic_dbscan import DynamicDBSCAN, check_unique_ids, claim_index
from .hashing import GridLSH


class BatchedDynamicDBSCAN(DynamicDBSCAN):
    def __init__(self, d, k, t, eps, seed: int = 0, use_device: bool = False,
                 attach_orphans: bool = True, lsh: Optional[GridLSH] = None,
                 repair: str = "exact"):
        super().__init__(d, k, t, eps, seed=seed,
                         attach_orphans=attach_orphans, lsh=lsh, repair=repair)
        self.use_device = use_device
        self._jax_fn = None

    # key space: kernel mixed keys (int32 pairs) instead of exact codes
    def _keys_of_batch(self, X: np.ndarray) -> List[list]:
        X = np.asarray(X, dtype=np.float32)
        if self.use_device:
            keys = np.asarray(self._device_hash(X))
        else:
            keys = self.lsh.device_keys_batch(X)
        return [
            [keys[j, i].tobytes() for i in range(self.t)]
            for j in range(X.shape[0])
        ]

    def _device_hash(self, X: np.ndarray):
        import jax.numpy as jnp

        from repro.kernels import ops

        return ops.lsh_hash(
            jnp.asarray(X),
            jnp.asarray(self.lsh.eta.astype(np.float32)),
            jnp.asarray(self.lsh.mixers),
            inv_cell=self.lsh.inv_cell,
            impl="pallas_interpret" if self.use_device == "interpret" else None,
        )

    def add_point(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        return self.add_batch(
            np.asarray(x, dtype=np.float64)[None], ids=[idx]
        )[0]

    def add_batch(self, X: np.ndarray,
                  ids: Optional[Sequence[Optional[int]]] = None) -> List[int]:
        """Hash the whole batch in one kernel call, then apply updates.

        ``ids`` optionally pins explicit indices (None entries auto-assign),
        mirroring the parent class's ``add_point(x, idx)`` contract.
        """
        X = np.asarray(X, dtype=np.float64)
        if X.ndim != 2 or X.shape[1] != self.d:
            raise ValueError(f"batch shape {X.shape} != (n, {self.d})")
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError("ids length must match batch size")
        keys = self._keys_of_batch(X)
        out = []
        for j in range(X.shape[0]):
            idx, self._next_idx = claim_index(
                self.points, self._next_idx,
                ids[j] if ids is not None else None,
            )
            out.append(self._add_with_keys(X[j], keys[j], idx))
        # batch boundary: squash the change feed (drain_deltas) so a
        # B-point run contributes O(touched ids), not O(B·t), entries
        self._compact_journal()
        return out

    def delete_batch(self, ids: Sequence[int]) -> None:
        check_unique_ids(ids)
        for i in ids:
            self.delete_point(i)
        self._compact_journal()
