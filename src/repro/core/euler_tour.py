"""Euler-Tour-Sequence dynamic forest (Henzinger–King via skip lists).

Stores, for every tree in the forest, the Euler tour of its doubled edges as
a sequence in a skip list (Tseng et al., ALENEX'19).  Every vertex ``v``
contributes a self-loop element ``(v,v)``; every tree edge ``{u,v}``
contributes two directed elements ``(u,v)`` and ``(v,u)``.

Operations (all O(log n) w.h.p.):
  * ``add_node(v)``      new singleton tree.
  * ``link(u, v)``       connect; no-op returning False if already connected
                         (the paper's LINK semantics).
  * ``cut(u, v)``        remove the edge if present, else False.
  * ``root(v)``          canonical identifier of v's tree (stable between
                         structural updates).
  * ``connected(u, v)``.
  * ``remove_node(v)``   v must be isolated.

The forest also maintains an explicit adjacency map so callers (the DBSCAN
layer) can enumerate tree neighbours — needed when re-linking non-core
points hanging off a demoted core point.

Tour algebra used below (linear sequences are rotations of the circular
tour):
  link:  rot_end(S_u, loop_u) ++ [(u,v)] ++ rot_end(S_v, loop_v) ++ [(v,u)]
  cut:   S = A ++ [(u,v)] ++ B ++ [(v,u)] ++ C   →   trees B and A ++ C
"""

from __future__ import annotations

from typing import Dict, Hashable, Iterator, Set, Tuple

from .skiplist import SkipListSeq, SLNode

NodeId = Hashable


class EulerTourForest:
    def __init__(self, seed: int = 0, backend: str = "skiplist"):
        if backend == "skiplist":
            self._sl = SkipListSeq(seed=seed)
        elif backend == "treap":
            from .treap_seq import TreapSeq

            self._sl = TreapSeq(seed=seed)
        else:
            raise ValueError(backend)
        self._loop: Dict[NodeId, SLNode] = {}
        self._edge: Dict[Tuple[NodeId, NodeId], SLNode] = {}
        self._adj: Dict[NodeId, Set[NodeId]] = {}
        self.n_links = 0  # instrumentation for benchmarks
        self.n_cuts = 0

    # ------------------------------------------------------------------ #
    # vertices
    # ------------------------------------------------------------------ #
    def add_node(self, v: NodeId) -> None:
        if v in self._loop:
            raise KeyError(f"node {v!r} already present")
        self._loop[v] = self._sl.make_node(("loop", v))
        self._adj[v] = set()

    def remove_node(self, v: NodeId) -> None:
        if self._adj[v]:
            raise ValueError(f"node {v!r} still has incident edges")
        del self._loop[v]
        del self._adj[v]

    def __contains__(self, v: NodeId) -> bool:
        return v in self._loop

    def __len__(self) -> int:
        return len(self._loop)

    def degree(self, v: NodeId) -> int:
        return len(self._adj[v])

    def neighbors(self, v: NodeId) -> Set[NodeId]:
        return self._adj[v]

    def has_edge(self, u: NodeId, v: NodeId) -> bool:
        return (u, v) in self._edge

    # ------------------------------------------------------------------ #
    # queries
    # ------------------------------------------------------------------ #
    def root(self, v: NodeId):
        """Unique identifier of v's tree (the paper's ROOT / GetCluster)."""
        return self._sl.representative(self._loop[v]).payload

    def connected(self, u: NodeId, v: NodeId) -> bool:
        return self._sl.same_seq(self._loop[u], self._loop[v])

    def tree_nodes(self, v: NodeId) -> Iterator[NodeId]:
        """All vertices in v's tree (linear time; oracles/debug only)."""
        for el in self._sl.iter_seq(self._loop[v]):
            kind, a = el.payload[0], el.payload[1]
            if kind == "loop":
                yield a

    # ------------------------------------------------------------------ #
    # structural updates
    # ------------------------------------------------------------------ #
    def _rotate_to_end(self, e) -> None:
        """Rotate e's (circular) sequence so the linear order ends at e."""
        nxt = self._next0(e)
        if nxt is None:
            return
        self._sl.split_after(e)
        # pieces: L = [.. e], R = [nxt ..]; rotated = R ++ L
        self._sl.concat(nxt, e)

    def link(self, u: NodeId, v: NodeId) -> bool:
        """Add edge {u,v} if u and v are in different trees."""
        lu, lv = self._loop[u], self._loop[v]
        if self._sl.same_seq(lu, lv):
            return False
        self._rotate_to_end(lu)
        self._rotate_to_end(lv)
        euv = self._sl.make_node(("edge", u, v))
        evu = self._sl.make_node(("edge", v, u))
        self._edge[(u, v)] = euv
        self._edge[(v, u)] = evu
        # S_u(ends at loop_u) ++ [euv] ++ S_v(ends at loop_v) ++ [evu]
        self._sl.concat(lu, euv)
        self._sl.concat(euv, lv)
        self._sl.concat(lv, evu)
        self._adj[u].add(v)
        self._adj[v].add(u)
        self.n_links += 1
        return True

    def cut(self, u: NodeId, v: NodeId) -> bool:
        """Remove edge {u,v} if present."""
        e1 = self._edge.get((u, v))
        if e1 is None:
            return False
        e2 = self._edge[(v, u)]
        if not self._before(e1, e2):
            e1, e2 = e2, e1
        # S = A ++ [e1] ++ B ++ [e2] ++ C
        p1 = self._prev0(e1)
        n2 = self._next0(e2)
        self._split_before(e1)
        self._sl.split_after(e1)  # isolates ... wait: [e1 .. e2 .. C]
        # after split_before(e1): A | [e1..e2..C];
        # split_after(e1): A | [e1] | B' where B' = B ++ [e2] ++ C
        self._split_before(e2)  # B' → B | [e2 ..C]
        self._sl.split_after(e2)  # → [e2] | C
        # tree 1: B (nonempty: contains at least loop of the far endpoint)
        # tree 2: A ++ C (one may be empty, never both)
        if p1 is not None and n2 is not None:
            self._sl.concat(p1, n2)
        del self._edge[(u, v)]
        del self._edge[(v, u)]
        self._adj[u].discard(v)
        self._adj[v].discard(u)
        self.n_cuts += 1
        return True

    # ------------------------------------------------------------------ #
    # helpers
    # ------------------------------------------------------------------ #
    def _split_before(self, e) -> None:
        p = self._prev0(e)
        if p is not None:
            self._sl.split_after(p)

    def _before(self, e1, e2) -> bool:
        """True iff e1 precedes e2 in their common sequence."""
        nxt = self._next0(e1)
        self._sl.split_after(e1)
        ans = not self._sl.same_seq(e1, e2)
        if nxt is not None:  # undo
            self._sl.concat(e1, nxt)
        return ans

    @staticmethod
    def _prev0(e):
        if hasattr(e, "prev"):
            return e.prev[0]
        # treap: in-order predecessor
        if e.left is not None:
            t = e.left
            while t.right is not None:
                t = t.right
            return t
        cur = e
        while cur.parent is not None and cur.parent.left is cur:
            cur = cur.parent
        return cur.parent

    @staticmethod
    def _next0(e):
        if hasattr(e, "next"):
            return e.next[0]
        if e.right is not None:
            t = e.right
            while t.left is not None:
                t = t.left
            return t
        cur = e
        while cur.parent is not None and cur.parent.right is cur:
            cur = cur.parent
        return cur.parent
