"""Clustering quality metrics: ARI and NMI (sklearn-compatible semantics),
implemented from scratch (the container has no sklearn)."""

from __future__ import annotations

import numpy as np


def _contingency(a: np.ndarray, b: np.ndarray):
    a = np.asarray(a)
    b = np.asarray(b)
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    na, nb = ai.max() + 1, bi.max() + 1
    m = np.zeros((na, nb), dtype=np.int64)
    np.add.at(m, (ai, bi), 1)
    return m


def _comb2(x):
    return x * (x - 1) / 2.0


def adjusted_rand_index(labels_true, labels_pred) -> float:
    labels_true = np.asarray(labels_true)
    labels_pred = np.asarray(labels_pred)
    if labels_true.shape != labels_pred.shape:
        raise ValueError(
            f"label shape mismatch: {labels_true.shape} vs "
            f"{labels_pred.shape}")
    # degenerate streams: no points, or a single point — the labellings
    # carry no pair information, and identical-partition conventions
    # (incl. two all-noise labellings) say perfect agreement
    if labels_true.size <= 1:
        return 1.0
    m = _contingency(labels_true, labels_pred)
    n = m.sum()
    sum_comb = _comb2(m).sum()
    sum_a = _comb2(m.sum(axis=1)).sum()
    sum_b = _comb2(m.sum(axis=0)).sum()
    exp = sum_a * sum_b / _comb2(n) if n > 1 else 0.0
    max_idx = 0.5 * (sum_a + sum_b)
    if max_idx == exp:
        return 1.0
    return float((sum_comb - exp) / (max_idx - exp))


def _entropy(counts: np.ndarray) -> float:
    p = counts[counts > 0].astype(np.float64)
    p = p / p.sum()
    return float(-(p * np.log(p)).sum())


def normalized_mutual_info(labels_true, labels_pred, average: str = "arithmetic") -> float:
    labels_true = np.asarray(labels_true)
    labels_pred = np.asarray(labels_pred)
    if labels_true.shape != labels_pred.shape:
        raise ValueError(
            f"label shape mismatch: {labels_true.shape} vs "
            f"{labels_pred.shape}")
    if labels_true.size == 0:
        return 1.0
    m = _contingency(labels_true, labels_pred).astype(np.float64)
    n = m.sum()
    if n == 0:
        return 0.0
    pij = m / n
    pi = pij.sum(axis=1, keepdims=True)
    pj = pij.sum(axis=0, keepdims=True)
    nz = pij > 0
    mi = float((pij[nz] * np.log(pij[nz] / (pi @ pj)[nz])).sum())
    hu = _entropy(m.sum(axis=1))
    hv = _entropy(m.sum(axis=0))
    if hu == 0.0 and hv == 0.0:
        return 1.0
    if average == "arithmetic":
        denom = 0.5 * (hu + hv)
    elif average == "geometric":
        denom = np.sqrt(hu * hv)
    else:
        raise ValueError(average)
    return float(mi / denom) if denom > 0 else 0.0
