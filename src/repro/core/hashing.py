"""Grid LSH family of Esfandiari–Mirrokni–Zhong (Definition 3).

``h_i(x) = floor((x + eta_i * 1_d) / (2 eps))`` with ``eta_i ~ U[0, 2 eps)``,
one scalar offset per table (the paper shifts every coordinate by the same
``eta``).  Two points share a bucket in table ``i`` iff their integer code
vectors are identical; we key buckets by the raw little-endian bytes of the
code vector (exact — no compression on the host path).

The Pallas kernel in ``repro.kernels.lsh_hash`` computes 64-bit mixed keys
on-device for high-throughput batch hashing; :meth:`mixed_keys_batch` is the
bit-exact host mirror used to validate it and to drive the batched update
path.
"""

from __future__ import annotations

import numpy as np

# murmur3 finalizer constants (int32 wrap-around; mirror of kernels/ref.py)
_MIX_A = np.int32(-1975444243)
_MIX_B = np.int32(-1029739211)


class GridLSH:
    def __init__(self, d: int, eps: float, t: int, seed: int = 0):
        if eps <= 0:
            raise ValueError("eps must be positive")
        self.d = int(d)
        self.eps = float(eps)
        self.t = int(t)
        rng = np.random.default_rng(seed)
        # scalar offset per table, broadcast over coordinates (eta * 1_d)
        self.eta = rng.uniform(0.0, 2.0 * eps, size=t).astype(np.float64)
        self.inv_cell = 1.0 / (2.0 * eps)
        # two families of per-(table, dim) odd int32 multipliers for the
        # on-device mixed-key path (matches kernels/lsh_hash bit-for-bit)
        self.mixers = (
            rng.integers(1, 2**31 - 1, size=(2, t, d), dtype=np.int64).astype(
                np.int32
            )
            | np.int32(1)
        )

    # ------------------------------------------------------------------ #
    # exact (host) path
    # ------------------------------------------------------------------ #
    def codes(self, x: np.ndarray) -> np.ndarray:
        """(d,) -> (t, d) int64 grid codes."""
        return np.floor((x[None, :] + self.eta[:, None]) * self.inv_cell).astype(
            np.int64
        )

    def keys(self, x: np.ndarray) -> list:
        """(d,) -> list of t hashable bucket keys (exact)."""
        c = self.codes(np.asarray(x, dtype=np.float64))
        return [c[i].tobytes() for i in range(self.t)]

    def codes_batch(self, X: np.ndarray, tables: int = None) -> np.ndarray:
        """(n, d) -> (n, t, d) int64 grid codes.

        ``tables=m`` restricts the pass to the first ``m`` tables (the
        shard router only needs table 0), bit-identical to slicing the
        full result."""
        X = np.asarray(X, dtype=np.float64)
        eta = self.eta if tables is None else self.eta[:tables]
        return np.floor(
            (X[:, None, :] + eta[None, :, None]) * self.inv_cell
        ).astype(np.int64)

    def keys_batch(self, X: np.ndarray) -> list:
        """(n, d) -> list over n of lists of t bucket keys."""
        codes = self.codes_batch(X)
        n = codes.shape[0]
        return [[codes[j, i].tobytes() for i in range(self.t)] for j in range(n)]

    # ------------------------------------------------------------------ #
    # mixed-key path (mirrors kernels/lsh_hash.py bit-for-bit)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _avalanche(h: np.ndarray) -> np.ndarray:
        def lsr(v, s):  # logical shift right on int32
            return (v.view(np.uint32) >> np.uint32(s)).view(np.int32)

        h = h ^ lsr(h, 16)
        h = (h * _MIX_A).astype(np.int32)
        h = h ^ lsr(h, 13)
        h = (h * _MIX_B).astype(np.int32)
        h = h ^ lsr(h, 16)
        return h

    def device_keys_batch(self, X: np.ndarray, tables: int = None) -> np.ndarray:
        """(n, d) -> (n, t, 2) int32 keys; bit-exact numpy mirror of the
        Pallas kernel (f32 grid quantisation + two int32 universal mixes).

        Used to validate the kernel and as the host fallback for the
        batched update path.  Spurious cross-code collisions ~ 2^-64.
        ``tables=m`` restricts the pass to the first ``m`` tables
        (elementwise per table, so bit-identical to slicing).
        """
        X32 = np.asarray(X, dtype=np.float32)
        eta = self.eta if tables is None else self.eta[:tables]
        mixers = self.mixers if tables is None else self.mixers[:, :tables]
        codes = np.floor(
            (X32[:, None, :] + eta.astype(np.float32)[None, :, None])
            * np.float32(self.inv_cell)
        ).astype(np.int32)  # (n, t, d)
        with np.errstate(over="ignore"):
            acc_a = (codes * mixers[0][None]).sum(axis=-1, dtype=np.int32)
            acc_b = (codes * mixers[1][None]).sum(axis=-1, dtype=np.int32)
            out = np.stack(
                [self._avalanche(acc_a), self._avalanche(acc_b)], axis=-1
            )
        return out
