"""SampledCoreDBSCAN — the DBSCAN++-style sampled-core approximate engine.

Jang & Jiang (2019) show that running the density test on a uniform
sample of the points — and attaching the rest to the sampled cores —
preserves clustering quality at a fraction of the maintenance cost.
This engine is that idea grafted onto the SoA exact engine
(:class:`~repro.core.soa.SoADynamicDBSCAN`): every point still enters
the bucket directory (membership, occupancy, attachment scans are
unchanged), but **support** — and with it the core set — is computed
over a second per-slot occupancy array ``_ssize`` counting only the
*sampled* members.  A point is core iff it is sampled and one of its
buckets holds >= k_s sampled members, where ``k_s = max(1, round(k *
sample_rate))`` is the sampled analogue of the exact threshold: the
expected sampled occupancy of a bucket with k total members is k *
sample_rate, so testing the sampled count against k_s keeps the density
test an unbiased estimate of the exact ">= k total neighbors" — the
same rescaling DBSCAN++ applies to minPts.  Non-sampled points can only
ever be border points, attached to sampled cores through the existing
grab/scan event machinery.

Sampling is a **deterministic hash of the point id** (splitmix64 of
``id`` mixed with ``approx_seed``), not an RNG draw:

  * stable under deletion — removing points never changes who else is
    sampled, so the sampled configuration stays a pure function of the
    live set (the same property that makes the exact engine's support
    history-free);
  * identical across shards and replicas — ids are global, so every
    party (inner engines, the boundary bridge, a restored snapshot)
    agrees on the sample with no coordination;
  * nothing to snapshot beyond ``(sample_rate, approx_seed)``, which
    live in the config.

At ``sample_rate=1.0`` every mask is all-true, ``_ssize`` coincides
with ``_bsize``, and every hook degenerates to the parent's exact
behavior — the engine is *bit-identical* to the SoA exact engine, which
the oracle test in ``tests/test_tiered.py`` pins down.

The batch support pass stays one kernel call: the sampled occupancy
gather runs through ``repro.kernels.bucket_ops.bucket_core_stats`` on
the device path, fed ``_ssize`` instead of ``_bsize``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from .hashing import GridLSH
from .soa import _EMPTY_MEMBERS, SoADynamicDBSCAN

_M64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB


def sampled_mask(ids: Sequence[int], rate: float, seed: int) -> np.ndarray:
    """(n,) bool: which of ``ids`` are in the deterministic sample.

    splitmix64 of ``id + seed·golden``; a point is sampled when the top
    53 bits of the hash, read as a uniform in [0, 1), fall below
    ``rate``.  Pure function of ``(id, rate, seed)`` — the single source
    of truth every consumer (engine, bridge, adapter, tests) shares.
    """
    ids_a = np.asarray(list(ids), dtype=np.int64).astype(np.uint64)
    if rate >= 1.0:
        return np.ones(len(ids_a), dtype=bool)
    z = ids_a + np.uint64((seed * _GOLDEN + _GOLDEN) & _M64)
    z ^= z >> np.uint64(30)
    z = z * np.uint64(_MIX1)
    z ^= z >> np.uint64(27)
    z = z * np.uint64(_MIX2)
    z ^= z >> np.uint64(31)
    thresh = np.uint64(int(rate * (1 << 53)))
    return (z >> np.uint64(11)) < thresh


def is_sampled(idx: int, rate: float, seed: int) -> bool:
    """Scalar mirror of :func:`sampled_mask` (bit-identical)."""
    if rate >= 1.0:
        return True
    z = (int(idx) + seed * _GOLDEN) & _M64
    z = (z + _GOLDEN) & _M64
    z ^= z >> 30
    z = (z * _MIX1) & _M64
    z ^= z >> 27
    z = (z * _MIX2) & _M64
    z ^= z >> 31
    return (z >> 11) < int(rate * (1 << 53))


class SampledCoreDBSCAN(SoADynamicDBSCAN):
    """Sampled-core approximate dynamic DBSCAN over the SoA layout."""

    def __init__(self, d: int, k: int, t: int, eps: float, seed: int = 0,
                 use_device: bool = False, attach_orphans: bool = True,
                 lsh: Optional[GridLSH] = None, repair: str = "exact",
                 sample_rate: float = 1.0, approx_seed: int = 0):
        if not 0.0 < sample_rate <= 1.0:
            raise ValueError(
                f"sample_rate must be in (0, 1], got {sample_rate}")
        self.sample_rate = float(sample_rate)
        self.approx_seed = int(approx_seed)
        super().__init__(d, k, t, eps, seed=seed, use_device=use_device,
                         attach_orphans=attach_orphans, lsh=lsh,
                         repair=repair)
        # sampled-analogue support threshold (degenerates to k at 1.0,
        # keeping the rate=1.0 oracle bit-identical to the exact engine)
        self.core_k = max(1, int(round(self.k * self.sample_rate)))
        # sampled occupancy per slot — the sizes support runs on; grown
        # in lockstep with _bsize by _ensure_slots
        self._ssize = np.zeros(len(self._bsize), np.int32)
        # sampled members per slot, maintained alongside _members: the
        # core-candidate pool crossings/demotions/scans/re-links walk.
        # Without it every deleted core's border re-links would rescan
        # full buckets that are mostly non-sampled.
        self._smembers: Dict[int, Set[int]] = {}

    # ------------------------------------------------------------------ #
    # sampling hooks (see SoADynamicDBSCAN; all-true masks at rate=1.0
    # make every one of these the parent's exact behavior)
    # ------------------------------------------------------------------ #
    def _elig_mask(self, ids: Sequence[int]) -> Optional[np.ndarray]:
        return sampled_mask(ids, self.sample_rate, self.approx_seed)

    def _core_candidate(self, m: int) -> bool:
        return is_sampled(m, self.sample_rate, self.approx_seed)

    def _grab_skip(self, s: int) -> bool:
        # skip only when every member is a final core: all sampled
        # members core (_ssize >= k_s) and no non-sampled members at all
        return (self._ssize[s] >= self.core_k
                and self._bsize[s] == self._ssize[s])

    def _core_sizes(self, ns: int) -> np.ndarray:
        return self._ssize[:ns]

    def _core_members(self, s: int) -> Set[int]:
        return self._smembers.get(s) or _EMPTY_MEMBERS

    def _member_discard(self, s: int, idx: int) -> None:
        # the full _members sets are never populated here (see
        # _add_members), so only the sampled view needs updating
        if self._core_candidate(idx):
            sm = self._smembers.get(s)
            if sm is not None:
                sm.discard(idx)

    def _add_members(self, slots: np.ndarray, out: List[int]) -> None:
        # Deliberately does NOT call super(): every hot-path consumer of
        # bucket membership goes through _core_members, and occupancy /
        # emptiness tests read _bsize, so the engine never needs the
        # full per-slot member sets — maintaining them for the ~9/10
        # non-sampled points would cost more than the entire sampled
        # bookkeeping.  _members entries stay as the empty sets
        # _alloc_slot seeds.
        m = sampled_mask(out, self.sample_rate, self.approx_seed)
        sub = np.nonzero(m)[0]
        if not len(sub):
            return
        ids_s = [out[j] for j in sub]
        for i in range(self.t):
            col = slots[sub, i]
            order = np.argsort(col, kind="stable")
            sorted_ids = [ids_s[j] for j in order]
            cs = col[order]
            bounds = np.nonzero(cs[1:] != cs[:-1])[0] + 1
            lo = 0
            for hi in list(bounds) + [len(cs)]:
                self._smembers.setdefault(int(cs[lo]), set()).update(
                    sorted_ids[lo:hi])
                lo = hi

    def _free_slot(self, s: int) -> None:
        super()._free_slot(s)
        self._smembers.pop(s, None)

    def _ensure_slots(self, need: int) -> None:
        super()._ensure_slots(need)
        if len(self._ssize) < len(self._bsize):
            self._ssize = np.concatenate([
                self._ssize,
                np.zeros(len(self._bsize) - len(self._ssize), np.int32)])

    def _batch_stats(self, slots: np.ndarray, flat: np.ndarray, ns: int,
                     smask: Optional[np.ndarray]):
        """Full occupancy drives membership; sampled occupancy drives
        support.  Still one kernel call per batch on the device path —
        ``bucket_core_stats`` just reads ``_ssize``."""
        rows_s = np.nonzero(smask)[0]
        if self.use_device:
            import jax.numpy as jnp

            from repro.kernels import ops

            impl = ("pallas_interpret" if self.use_device == "interpret"
                    else None)
            jslots = jnp.asarray(slots)
            delta = np.asarray(ops.slot_counts(jslots, n_slots=ns, impl=impl))
            self._bsize[:ns] += delta
            sdelta = (delta if len(rows_s) == len(smask) else np.asarray(
                ops.slot_counts(jnp.asarray(slots[rows_s]), n_slots=ns,
                                impl=impl)))
            self._ssize[:ns] += sdelta
            supp, _core = ops.bucket_core_stats(
                jslots, jnp.asarray(self._ssize[:ns]), k=self.core_k,
                impl=impl)
            supp = np.asarray(supp)
        else:
            delta = np.bincount(flat, minlength=ns).astype(np.int32)
            self._bsize[:ns] += delta
            sdelta = np.bincount(
                slots[rows_s].ravel(), minlength=ns).astype(np.int32)
            self._ssize[:ns] += sdelta
            supp = np.add.reduce(
                self._ssize[slots] >= self.core_k, axis=1, dtype=np.int32)
        supp = np.where(smask, supp, 0).astype(np.int32)
        core_new = self._ssize[:ns]
        return core_new - sdelta, core_new, self._ssize[slots], supp

    def _bucket_shrink(self, s: int, idx: int) -> bool:
        self._bsize[s] -= 1
        if not self._core_candidate(idx):
            return False
        self._ssize[s] -= 1
        return self._ssize[s] == self.core_k - 1

    def _apply_occupancy_delta(self, dep: np.ndarray, core_dep: np.ndarray,
                               ns: int) -> None:
        super()._apply_occupancy_delta(dep, core_dep, ns)
        self._ssize[:ns] -= core_dep

    def _rebuild_support(self, slots: np.ndarray,
                         ids: List[int]) -> np.ndarray:
        m = sampled_mask(ids, self.sample_rate, self.approx_seed)
        ns = self._n_slots
        self._ssize[:ns] = np.bincount(
            slots[m].ravel(), minlength=ns).astype(np.int32)
        supp = np.add.reduce(self._ssize[slots] >= self.core_k, axis=1)
        return np.where(m, supp, 0)

    # ------------------------------------------------------------------ #
    # diagnostics
    # ------------------------------------------------------------------ #
    def n_sampled(self) -> int:
        """Live sampled points (the core-candidate population)."""
        if not self._row:
            return 0
        return int(sampled_mask(list(self._row), self.sample_rate,
                                self.approx_seed).sum())

    def _check_counts(self, rows: np.ndarray, ids: np.ndarray,
                      core_ids: Set[int]) -> None:
        m = sampled_mask(ids, self.sample_rate, self.approx_seed)
        slots = self._slots[rows]
        # 1. occupancy totals: full sizes count every live (point, table)
        #    pair; sampled sizes and the sampled member sets agree and
        #    carry only sampled live points.  (No full per-slot member
        #    sets exist to compare _bsize against — see _add_members.)
        live_slots = np.nonzero(self._bsize[:self._n_slots] > 0)[0]
        assert int(self._bsize[live_slots].sum()) == self.t * len(rows)
        sampled_live = {int(i) for i, smp in zip(ids, m) if smp}
        for s, sm in self._smembers.items():
            assert self._ssize[s] == len(sm), (s, self._ssize[s], len(sm))
            assert sm <= sampled_live, s
            # 2. buckets with >= k_s sampled members: sampled members core
            if len(sm) >= self.core_k:
                assert all(y in core_ids for y in sm)
        assert int(self._ssize[:self._n_slots].sum()) == sum(
            len(v) for v in self._smembers.values())
        assert int(self._ssize[:self._n_slots].sum()) == self.t * len(
            sampled_live)
        supp = np.where(m, np.add.reduce(self._ssize[slots] >= self.core_k,
                                         axis=1), 0)
        assert np.array_equal(supp, self._support[rows])
        # non-sampled points never hold support
        assert not np.any(self._support[rows][~m])
