"""EMZ — the static near-linear DBSCAN of Esfandiari et al. (AAAI'21).

Vectorised batch implementation used (a) as the paper's main baseline
("hash values for incoming points are computed once, and the graph is
recomputed after processing each batch") and (b) as the *semantic oracle*
for DynamicDBSCAN: with the same LSH family and the paper's Definition-4
core rule, the connected components must match the dynamic structure's
components exactly, because H is invariant to update order (§4.2).

Core rule: Definition 4 (any of the t buckets has >= k members).  The
original EMZ paper used a dedicated hash function for core determination;
the dynamic paper redefines cores over all t tables, and for a meaningful
equivalence test we follow the dynamic paper's definition here too.
"""

from __future__ import annotations

from typing import Optional

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import connected_components

from .dynamic_dbscan import NOISE
from .hashing import GridLSH


def _bucket_ids(codes_i: np.ndarray) -> np.ndarray:
    """(n, d) int64 codes -> (n,) dense bucket ids for one table."""
    _, inv = np.unique(codes_i, axis=0, return_inverse=True)
    return inv


def emz_cluster(
    X: np.ndarray,
    k: int,
    eps: float,
    t: int,
    seed: int = 0,
    lsh: Optional[GridLSH] = None,
    return_core: bool = False,
) -> np.ndarray:
    """Cluster X; returns labels (noise = -1), optionally the core mask.

    O(t·n·(d + log n)) — one sort per table dominates.
    """
    X = np.asarray(X, dtype=np.float64)
    n, d = X.shape
    if lsh is None:
        lsh = GridLSH(d, eps, t, seed)
    codes = lsh.codes_batch(X)  # (n, t, d)

    core = np.zeros(n, dtype=bool)
    bucket_of = np.empty((t, n), dtype=np.int64)
    for i in range(t):
        b = _bucket_ids(codes[:, i, :])
        bucket_of[i] = b
        sizes = np.bincount(b)
        core |= sizes[b] >= k

    rows, cols = [], []
    core_idx = np.flatnonzero(core)
    for i in range(t):
        b = bucket_of[i]
        # chain CORE points within each bucket in index order (paper's path)
        bc = b[core_idx]
        order = np.argsort(bc, kind="stable")  # core_idx already ascending
        s = core_idx[order]
        same = bc[order][1:] == bc[order][:-1]
        rows.append(s[:-1][same])
        cols.append(s[1:][same])

    # attach non-core points to one colliding core point (if any)
    attached_to = np.full(n, -1, dtype=np.int64)
    for i in range(t):
        b = bucket_of[i]
        nb = int(b.max()) + 1 if n else 0
        # first (lowest-index) core point per bucket
        first_core = np.full(nb, -1, dtype=np.int64)
        bc = b[core_idx]
        # reversed so the lowest index wins the final write
        first_core[bc[::-1]] = core_idx[::-1]
        cand = first_core[b]
        take = (~core) & (attached_to < 0) & (cand >= 0)
        attached_to[take] = cand[take]

    att = np.flatnonzero(attached_to >= 0)
    rows.append(att)
    cols.append(attached_to[att])

    rows = np.concatenate(rows) if rows else np.empty(0, dtype=np.int64)
    cols = np.concatenate(cols) if cols else np.empty(0, dtype=np.int64)
    g = sp.coo_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, n))
    _, comp = connected_components(g, directed=False)

    labels = comp.astype(np.int64)
    labels[(~core) & (attached_to < 0)] = NOISE
    if return_core:
        return labels, core
    return labels


class EMZRecompute:
    """Streaming wrapper: recompute the EMZ clustering after every batch
    (the paper's 'EMZ' baseline).  Hash codes are computed once per point
    and cached; the graph/labels are rebuilt from scratch per batch."""

    def __init__(self, d: int, k: int, t: int, eps: float, seed: int = 0,
                 lsh: Optional[GridLSH] = None):
        self.k, self.t, self.eps = k, t, eps
        self.lsh = lsh if lsh is not None else GridLSH(d, eps, t, seed)
        self._X: list = []

    def add_batch(self, Xb: np.ndarray) -> np.ndarray:
        self._X.append(np.asarray(Xb, dtype=np.float64))
        X = np.concatenate(self._X, axis=0)
        return emz_cluster(X, self.k, self.eps, self.t, lsh=self.lsh)
