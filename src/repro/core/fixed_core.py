"""EMZFixedCore — the ablation variant proposed in the paper's §5.

Processes the initial batch with the EMZ method, then freezes the core set:
every subsequent point is treated as non-core and assigned to the cluster
of the first core point it collides with under any hash function (noise if
none).  Works well under random arrival order; degrades when clusters
arrive one at a time (Figure 2c) — which is exactly what the benchmark
reproduces.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .dynamic_dbscan import NOISE
from .hashing import GridLSH
from .static_emz import emz_cluster


class EMZFixedCore:
    def __init__(self, d: int, k: int, t: int, eps: float, seed: int = 0,
                 lsh: Optional[GridLSH] = None):
        self.k, self.t, self.eps = k, t, eps
        self.lsh = lsh if lsh is not None else GridLSH(d, eps, t, seed)
        self._initialised = False
        self._labels: list = []
        # bucket key -> cluster label of a core point in that bucket
        self._core_bucket_label: list = None

    def add_batch(self, Xb: np.ndarray) -> np.ndarray:
        Xb = np.asarray(Xb, dtype=np.float64)
        if not self._initialised:
            labels, core = emz_cluster(
                Xb, self.k, self.eps, self.t, lsh=self.lsh, return_core=True
            )
            self._labels = list(labels)
            self._core_bucket_label = [dict() for _ in range(self.t)]
            codes = self.lsh.codes_batch(Xb)
            for j in np.flatnonzero(core):
                for i in range(self.t):
                    key = codes[j, i].tobytes()
                    self._core_bucket_label[i].setdefault(key, int(labels[j]))
            self._initialised = True
            return np.asarray(self._labels)

        codes = self.lsh.codes_batch(Xb)
        for j in range(Xb.shape[0]):
            lab = NOISE
            for i in range(self.t):
                key = codes[j, i].tobytes()
                hit = self._core_bucket_label[i].get(key)
                if hit is not None:
                    lab = hit
                    break
            self._labels.append(lab)
        return np.asarray(self._labels)
