"""SoADynamicDBSCAN — the vectorised structure-of-arrays engine core.

Same clustering as :class:`~repro.core.dynamic_dbscan.DynamicDBSCAN`
(Definition 4 cores, Thm-2 component structure, identical border-point
anchoring), different state layout: instead of per-point dicts and
per-bucket Python objects walked point-by-point, the engine keeps

  * a row store of fixed-dtype arrays — ids (i64), points (f64), mixed
    bucket keys (i32 pairs, the ``lsh_hash`` kernel family), bucket
    *slots* (i32), support counts (i32), attach anchors (i64);
  * a bucket directory mapping each table's key bytes to a dense slot id,
    with occupancy in one i32 array and membership in per-slot sets;
  * an epoch-cached connectivity labelling over the *configuration-
    determined* chain edges (see below) instead of an eagerly-maintained
    Euler-tour forest.

``add_batch`` is one vectorised pass per batch — hash kernel → slot
resolution → occupancy deltas → support gather → core transitions
(``repro.kernels.bucket_ops`` on the device path) — with per-point Python
work only for the *events* of the sequential semantics: threshold
crossings, orphan grabs, and border attachment.

Why this is exact, not approximate: support counts, the core set, and the
per-bucket core chains are pure functions of the current point
configuration, and Thm 2 makes core-partition connectivity configuration-
determined too — so they need no incremental history, only the current
arrays.  The *only* history-dependent state is which cluster a border
point anchors to.  The batch path replays the sequential engine's
attachment decisions exactly by event time: a point promoted when bucket
``b`` crosses the threshold at batch step ``s`` grabs unattached orphans
at time ``(s, id)``, a non-core insert at step ``j`` scans its buckets'
cores-at-time-``j`` in table order — the same order `DynamicDBSCAN`
processes ``sorted(promoted)`` and ``_link_non_core_point``.  Transient
states (a point grabbed mid-batch and promoted later the same batch)
cancel out of the final configuration and of the compacted journal, so
they are skipped rather than simulated.

Connectivity is rebuilt per *epoch* (any mutation invalidates, first
query rebuilds): chain edges are consecutive core rows per slot, and the
component labelling is a vectorised Shiloach–Vishkin hook+shortcut pass
(the data-parallel connectivity of Wang et al.'s parallel DBSCAN) — no
scipy dependency, O(E log n) array work, amortised across every label
query in the epoch.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from ..obs import NULL_OBS
from .dynamic_dbscan import NOISE, check_unique_ids, claim_index
from .hashing import GridLSH

_KEY_W = 8  # mixed keys: 2 int32 words per (point, table)
_EMPTY_MEMBERS: frozenset = frozenset()  # read-only _core_members default


class _LiveView:
    """Membership view over the committed id map plus a batch's staged
    claims — lets ``claim_index`` reject duplicates before any state
    mutation (the batch path is atomic on bad ids, unlike the sequential
    engine's partial prefix)."""

    __slots__ = ("a", "b")

    def __init__(self, a, b):
        self.a, self.b = a, b

    def __contains__(self, idx) -> bool:
        return idx in self.a or idx in self.b


def _sv_components(n_rows: int, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Shiloach–Vishkin style connectivity: parent pointer per row,
    hook-to-minimum + pointer-jumping until a fixpoint.  Returns the
    fully-compressed parent array (each row points at its component's
    minimum row).  O((E + n) log n) pure array work."""
    parent = np.arange(n_rows, dtype=np.int64)
    if len(a) == 0:
        return parent
    while True:
        pa, pb = parent[a], parent[b]
        lo = np.minimum(pa, pb)
        hi = np.maximum(pa, pb)
        np.minimum.at(parent, hi, lo)
        # shortcut: full pointer-jumping compression
        while True:
            pp = parent[parent]
            if np.array_equal(pp, parent):
                break
            parent = pp
        if np.array_equal(parent[a], parent[b]):
            return parent


class SoADynamicDBSCAN:
    """Array-backed exact dynamic DBSCAN (drop-in for the dict engines)."""

    def __init__(self, d: int, k: int, t: int, eps: float, seed: int = 0,
                 use_device: bool = False, attach_orphans: bool = True,
                 lsh: Optional[GridLSH] = None, repair: str = "exact"):
        if repair not in ("exact", "paper"):
            raise ValueError(repair)
        self.d, self.k, self.t, self.eps = d, int(k), int(t), float(eps)
        # the support threshold applied to _core_sizes.  Equal to k here;
        # the sampled-core subclass rescales it to the sampled analogue
        # max(1, round(k * sample_rate)) so the density test stays an
        # unbiased estimate of ">= k total neighbors".
        self.core_k = self.k
        self.lsh = lsh if lsh is not None else GridLSH(d, eps, t, seed)
        if self.lsh.t != self.t or self.lsh.d != d:
            raise ValueError("lsh family incompatible with (d, t)")
        self.use_device = use_device
        self.attach_orphans = attach_orphans

        cap = 256
        self._cap = cap
        self._top = 0                      # high-water row
        self._ids = np.full(cap, -1, np.int64)
        self._pts = np.zeros((cap, d), np.float64)
        self._keys32 = np.zeros((cap, t, 2), np.int32)
        self._slots = np.zeros((cap, t), np.int32)
        self._support = np.zeros(cap, np.int32)
        self._attach = np.full(cap, -1, np.int64)
        self._row: Dict[int, int] = {}     # id -> row (insertion-ordered)
        self._free_rows: List[int] = []

        # bucket directory: per-table key-bytes -> dense slot id
        self._dir: List[Dict[bytes, int]] = [dict() for _ in range(t)]
        self._slot_key: List[Optional[Tuple[int, bytes]]] = []
        self._bsize = np.zeros(256, np.int32)  # capacity-doubling
        self._n_slots = 0
        self._members: Dict[int, Set[int]] = {}
        self._free_slots: List[int] = []

        self.anchored: Dict[int, Set[int]] = {}
        self._next_idx = 0
        self._journal: Optional[
            List[Tuple[int, Optional[int], Optional[int]]]] = None
        # epoch cache: row -> component handle for core rows (None = dirty)
        self._comp: Optional[np.ndarray] = None

        # instrumentation (adapter stats())
        self.n_epoch_rebuilds = 0
        self.n_promotions = 0
        self.n_demotions = 0
        self.n_grab_events = 0
        self.n_scan_events = 0
        self.obs = NULL_OBS

    # ------------------------------------------------------------------ #
    # capacity management
    # ------------------------------------------------------------------ #
    def _ensure_rows(self, need: int) -> None:
        if need <= self._cap:
            return
        cap = self._cap
        while cap < need:
            cap *= 2
        grow = cap - self._cap
        self._ids = np.concatenate([self._ids, np.full(grow, -1, np.int64)])
        self._pts = np.concatenate(
            [self._pts, np.zeros((grow, self.d), np.float64)])
        self._keys32 = np.concatenate(
            [self._keys32, np.zeros((grow, self.t, 2), np.int32)])
        self._slots = np.concatenate(
            [self._slots, np.zeros((grow, self.t), np.int32)])
        self._support = np.concatenate(
            [self._support, np.zeros(grow, np.int32)])
        self._attach = np.concatenate(
            [self._attach, np.full(grow, -1, np.int64)])
        self._cap = cap

    def _ensure_slots(self, need: int) -> None:
        if need <= len(self._bsize):
            return
        cap = len(self._bsize)
        while cap < need:
            cap *= 2
        self._bsize = np.concatenate(
            [self._bsize, np.zeros(cap - len(self._bsize), np.int32)])

    def _alloc_slot(self, table: int, key: bytes) -> int:
        if self._free_slots:
            s = self._free_slots.pop()
            self._slot_key[s] = (table, key)
        else:
            s = self._n_slots
            self._slot_key.append((table, key))
            self._n_slots += 1
        self._dir[table][key] = s
        self._members[s] = set()
        return s

    def _free_slot(self, s: int) -> None:
        table, key = self._slot_key[s]  # type: ignore[misc]
        del self._dir[table][key]
        self._slot_key[s] = None
        self._members.pop(s, None)
        self._free_slots.append(s)

    # ------------------------------------------------------------------ #
    # hashing / slot resolution
    # ------------------------------------------------------------------ #
    def _hash_batch(self, X: np.ndarray) -> np.ndarray:
        """(B, d) -> (B, t, 2) int32 mixed keys (kernel key family)."""
        X32 = np.asarray(X, dtype=np.float32)
        if self.use_device:
            import jax.numpy as jnp

            from repro.kernels import ops

            return np.asarray(ops.lsh_hash(
                jnp.asarray(X32),
                jnp.asarray(self.lsh.eta.astype(np.float32)),
                jnp.asarray(self.lsh.mixers),
                inv_cell=self.lsh.inv_cell,
                impl=("pallas_interpret" if self.use_device == "interpret"
                      else None),
            ))
        return self.lsh.device_keys_batch(X32)

    # hot-path
    def _resolve_slots(self, keys32: np.ndarray) -> np.ndarray:
        """(B, t, 2) keys -> (B, t) slot ids, creating directory entries
        for unseen keys.  One ``np.unique`` per table; Python touches only
        the unique keys, never the B·t key instances."""
        B = keys32.shape[0]
        self._ensure_slots(self._n_slots + B * self.t)
        void = np.ascontiguousarray(keys32).view(
            np.dtype((np.void, _KEY_W)))[..., 0]          # (B, t)
        slots = np.empty((B, self.t), np.int32)
        lut_buf = np.empty(B, np.int32)  # scratch reused across tables
        for i in range(self.t):
            uniq, inv = np.unique(void[:, i], return_inverse=True)
            table = self._dir[i]
            lut = lut_buf[:len(uniq)]
            for u, v in enumerate(uniq):
                kb = v.tobytes()
                s = table.get(kb)
                lut[u] = self._alloc_slot(i, kb) if s is None else s
            slots[:, i] = lut[inv]
        return slots

    # ------------------------------------------------------------------ #
    # public API
    # ------------------------------------------------------------------ #
    def add_point(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        return self.add_batch(
            np.asarray(x, dtype=np.float64)[None], ids=[idx])[0]

    # hot-path
    def add_batch(self, X: np.ndarray,
                  ids: Optional[Sequence[Optional[int]]] = None) -> List[int]:
        X = np.asarray(X, dtype=np.float64)
        if X.ndim != 2 or X.shape[1] != self.d:
            raise ValueError(f"batch shape {X.shape} != (n, {self.d})")
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError("ids length must match batch size")
        B = X.shape[0]
        if B == 0:
            return []
        k, t = self.core_k, self.t

        # -- claim handles (atomic: duplicates raise before any mutation)
        staged: Dict[int, int] = {}
        live = _LiveView(self._row, staged)
        out: List[int] = []
        for j in range(B):
            idx, self._next_idx = claim_index(
                live, self._next_idx, ids[j] if ids is not None else None)
            staged[idx] = j
            out.append(idx)

        # -- one device pass: hash -> slots -> occupancy deltas.  smask
        #    marks the core-eligible batch points (None = all; the
        #    sampled-core subclass narrows it), and the "core sizes" the
        #    crossings run on are whatever _batch_stats says drives
        #    support — bucket occupancy here, sampled occupancy there.
        keys32 = self._hash_batch(X)
        slots = self._resolve_slots(keys32)
        ns = self._n_slots
        flat = slots.ravel()
        smask = self._elig_mask(out)
        core_old, core_new, occ_core, supp_batch = self._batch_stats(
            slots, flat, ns, smask)

        # -- threshold crossings: which slots crossed k, and at which step
        crossing = np.nonzero((core_old < k) & (core_new >= k))[0]
        cross_step = np.full(ns, B + 1, np.int64)      # B+1 = never crossed
        cross_step[core_new >= k] = -1                 # already >= k...
        if len(crossing):
            cross_step[crossing] = self._cross_steps(
                crossing, core_old, slots, smask)      # ...unless this batch

        # -- existing members of crossing buckets gain support (the
        #    sequential engine's "bucket crosses: every member gains")
        promoted_existing: Dict[int, int] = {}  # id -> core_time
        for s in crossing:
            step = int(cross_step[s])
            for m in self._core_members(int(s)):
                if not self._core_candidate(m):
                    continue
                r = self._row[m]
                self._support[r] += 1
                if self._support[r] == 1:
                    promoted_existing[m] = step
                elif m in promoted_existing:
                    # promotion time is the EARLIEST crossing bucket's step,
                    # not the first in slot-id order
                    promoted_existing[m] = min(promoted_existing[m], step)

        # -- membership: bulk per-slot set updates (grouped, C-speed)
        self._add_members(slots, out)
        step_of = staged  # id -> batch step, for event-time filtering

        # -- commit batch rows
        self._ensure_rows(self._top + B)
        rows = np.empty(B, np.int64)
        for j in range(B):
            r = self._free_rows.pop() if self._free_rows else self._top
            if r == self._top:
                self._top += 1
            rows[j] = r
            self._row[out[j]] = r
        self._ids[rows] = out
        self._pts[rows] = X
        self._keys32[rows] = keys32
        self._slots[rows] = slots
        self._support[rows] = supp_batch
        self._attach[rows] = -1

        # -- core_time per batch point: min over core buckets of
        #    max(insert step, bucket cross step); non-core = B+1
        steps = np.arange(B, dtype=np.int64)[:, None]
        cand = np.where(occ_core >= k,
                        np.maximum(cross_step[slots], steps), B + 1)
        if smask is not None:
            cand = np.where(smask[:, None], cand, B + 1)
        core_time = cand.min(axis=1)

        self._apply_insert_events(out, rows, slots, step_of, core_time,
                                  promoted_existing, occ_core)
        self._comp = None
        self._compact_journal()
        return out

    # ------------------------------------------------------------------ #
    # sampling hooks — the exact engine treats every point as core-
    # eligible; SampledCoreDBSCAN (core/approx.py) overrides these so
    # support runs on the sampled occupancy while membership/attachment
    # keep seeing every point.
    # ------------------------------------------------------------------ #
    def _elig_mask(self, ids: Sequence[int]) -> Optional[np.ndarray]:
        """(B,) bool core-eligibility of the given ids; None = all."""
        return None

    def _core_candidate(self, m: int) -> bool:
        """May ``m`` ever hold support (be a core point)?"""
        return True

    def _grab_skip(self, s: int) -> bool:
        """True when bucket ``s`` can hold no grabbable orphan (every
        member is a final core)."""
        return self._bsize[s] >= self.core_k

    def _core_sizes(self, ns: int) -> np.ndarray:
        """The per-slot sizes support thresholds run on (view)."""
        return self._bsize[:ns]

    def _core_members(self, s: int) -> Set[int]:
        """Members of slot ``s`` that may hold support or anchor a border
        — the pool crossings, demotions, scans and re-links walk.  The
        sampled-core subclass narrows it to the sampled members, which is
        what keeps deletion repair O(cores) instead of O(bucket)."""
        return self._members.get(s) or _EMPTY_MEMBERS

    def _member_discard(self, s: int, idx: int) -> None:
        """Remove ``idx`` from slot ``s``'s membership (single seam so
        subclasses keep any parallel member structures in sync)."""
        self._members[s].discard(idx)

    def _batch_stats(self, slots: np.ndarray, flat: np.ndarray, ns: int,
                     smask: Optional[np.ndarray]):
        """One array pass per insert batch — occupancy deltas + final
        per-point support, via the kernel pass (``use_device``) or its
        bit-exact numpy mirror.  Returns ``(core_old, core_new,
        occ_core, supp)``: the support-driving slot sizes before/after
        the batch, their per-(point, table) gather, and each batch
        point's final support."""
        if self.use_device:
            import jax.numpy as jnp

            from repro.kernels import ops

            impl = ("pallas_interpret" if self.use_device == "interpret"
                    else None)
            jslots = jnp.asarray(slots)
            delta = np.asarray(ops.slot_counts(jslots, n_slots=ns, impl=impl))
            self._bsize[:ns] += delta
            supp, _core = ops.bucket_core_stats(
                jslots, jnp.asarray(self._bsize[:ns]), k=self.core_k,
                impl=impl)
            supp = np.asarray(supp)
        else:
            delta = np.bincount(flat, minlength=ns).astype(np.int32)
            self._bsize[:ns] += delta
            supp = np.add.reduce(
                self._bsize[slots] >= self.core_k, axis=1, dtype=np.int32)
        new_sizes = self._bsize[:ns]
        return new_sizes - delta, new_sizes, self._bsize[slots], supp

    def _cross_steps(self, crossing: np.ndarray, core_old: np.ndarray,
                     slots: np.ndarray,
                     smask: Optional[np.ndarray]) -> np.ndarray:
        """Batch step at which each crossing slot reached size k: the
        (k - old_size)-th core-eligible arrival into the slot this batch.
        One stable argsort of the flat slot list; within a slot the order
        is by flat position, i.e. by batch step."""
        if smask is None:
            flat = slots.ravel()
            rows_map = None
        else:
            rows_map = np.nonzero(smask)[0]
            flat = slots[rows_map].ravel()
        order = np.argsort(flat, kind="stable")
        sf = flat[order]
        starts = np.searchsorted(sf, crossing)
        entry = starts + (self.core_k - core_old[crossing] - 1)
        steps = order[entry] // self.t
        return steps if rows_map is None else rows_map[steps]

    def _add_members(self, slots: np.ndarray, out: List[int]) -> None:
        for i in range(self.t):
            col = slots[:, i]
            order = np.argsort(col, kind="stable")
            sorted_ids = [out[j] for j in order]
            cs = col[order]
            bounds = np.nonzero(cs[1:] != cs[:-1])[0] + 1
            lo = 0
            for hi in list(bounds) + [len(cs)]:
                self._members[int(cs[lo])].update(sorted_ids[lo:hi])
                lo = hi

    # ------------------------------------------------------------------ #
    # insert-time events: promotions, orphan grabs, border scans
    # ------------------------------------------------------------------ #
    def _apply_insert_events(self, out, rows, slots, step_of, core_time,
                             promoted_existing, occ_final) -> None:
        """Replay the sequential engine's attachment decisions by event
        time (see module docstring).  All final-core batch points record a
        promotion; final-non-core batch points scan their buckets'
        cores-at-insert-time; promoted cores grab unattached orphans from
        their sub-threshold buckets at their promotion time."""
        k, B = self.k, len(out)
        INF = B + 1

        # promotion events: (time, id, slots_row) — batch cores + promoted
        # existing, exactly the sequential engine's sorted(promoted) sets
        events: List[Tuple[int, int, np.ndarray]] = []
        ctime: Dict[int, int] = {}
        core_js = np.nonzero(core_time <= B)[0]
        for j in core_js:
            ct = int(core_time[j])
            ctime[out[j]] = ct
            events.append((ct, out[j], slots[j]))
        for m, ct in promoted_existing.items():
            ctime[m] = ct
            r = self._row[m]
            old = int(self._attach[r]) if self._attach[r] >= 0 else None
            self._record(m, old, m)  # promotion delta (old = pre-batch)
            if old is not None:
                self.anchored[old].discard(m)
                self._attach[r] = -1
            events.append((ct, m, self._slots[r]))
        for j in core_js:
            self._record(out[j], None, out[j])
        self.n_promotions += len(events)

        # helper: is m core at time s (strictly before)?  -1 = pre-batch
        support = self._support
        row = self._row

        def _core_at(m: int, s: int) -> bool:
            ct = ctime.get(m)
            if ct is not None:
                return ct < s
            return support[row[m]] > 0 and m not in step_of

        # -- grab events: promoted core c, sub-threshold bucket, orphan y.
        # Orphan status (final support 0, no pre-batch anchor) is constant
        # through the replay — attachments only apply at the end — so the
        # loop is inverted: one vectorised sweep finds every orphan row,
        # and each orphan binary-searches its own slots' time-sorted event
        # lists for the earliest grab after its insertion.  A slot's list
        # is sorted by (time, id), so the first event with ct > step IS
        # min(ct, c) over that slot's qualifying grabs.  No orphan can
        # live in a slot the old per-slot walk skipped (all-core buckets
        # give every member support > 0), so no skip test is needed.
        best: Dict[int, Tuple[int, int]] = {}
        cand = (np.nonzero((support == 0) & (self._attach < 0)
                           & (self._ids != -1))[0]
                if self.attach_orphans and events else ())
        if len(cand):  # no orphans (dense exact case): skip event scatter
            tmp: Dict[int, List[Tuple[int, int]]] = {}
            for ct, c, srow in events:
                for s in srow:
                    tmp.setdefault(int(s), []).append((ct, c))
            evs_ct: Dict[int, np.ndarray] = {}
            evs_c: Dict[int, np.ndarray] = {}
            for s, lst in tmp.items():
                lst.sort()
                evs_ct[s] = np.fromiter((t for t, _ in lst), np.int64,
                                        len(lst))
                evs_c[s] = np.fromiter((c for _, c in lst), np.int64,
                                       len(lst))
            # only orphans sharing a bucket with a promotion can be
            # grabbed — with a stable core set (sampled tier) this drops
            # the persistent-noise sweep to near nothing
            ev_slots = np.fromiter(tmp, np.int64, len(tmp))
            ev_slots.sort()
            touch = np.isin(self._slots[cand], ev_slots).any(axis=1)
            cand = cand[touch]
        if len(cand):
            n_orph = len(cand)
            ids_c = self._ids[cand]
            steps = np.fromiter(
                (step_of.get(int(y), -1) for y in ids_c),
                np.int64, n_orph)
            S = self._slots[cand]                       # (n_orph, t)
            INF2 = np.iinfo(np.int64).max
            best_ct = np.full(n_orph, INF2, np.int64)
            best_c = np.full(n_orph, INF2, np.int64)
            # group (orphan, slot) pairs by slot: one bulk search per
            # slot instead of one Python bisect per pair
            flat = S.ravel()
            oidx = np.repeat(np.arange(n_orph), S.shape[1])
            order = np.argsort(flat, kind="stable")
            fs, fo = flat[order], oidx[order]
            cuts = np.nonzero(np.diff(fs))[0] + 1
            starts = np.concatenate([[0], cuts])
            ends = np.concatenate([cuts, [len(fs)]])
            for a, b in zip(starts, ends):
                s = int(fs[a])
                ect = evs_ct.get(s)
                if ect is None:
                    continue
                g = fo[a:b]
                pos = np.searchsorted(ect, steps[g], side="right")
                q = pos < len(ect)
                if not q.any():
                    continue
                g2, p2 = g[q], pos[q]
                ct2, c2 = ect[p2], evs_c[s][p2]
                upd = (ct2 < best_ct[g2]) | ((ct2 == best_ct[g2])
                                             & (c2 < best_c[g2]))
                if upd.any():
                    gi = g2[upd]
                    best_ct[gi] = ct2[upd]
                    best_c[gi] = c2[upd]
            for i in np.nonzero(best_ct < INF2)[0]:
                best[int(ids_c[i])] = (int(best_ct[i]),
                                       int(best_c[i]))

        # -- scan events: final-non-core batch points attach at insert.
        # A point m answers a scan at step j iff it is core strictly
        # before j: core time max(core_time_m, insert_step_m), with -1
        # for pre-batch cores.  Bulk form of "for each border, first
        # table whose slot holds such an m": one vectorised candidate
        # build over the final core set (restricted to the slots borders
        # actually touch), lexsorted by (slot, time, id) so a per-slot
        # slice is a time-sorted prefix-min table; then one grouped
        # searchsorted per touched slot.  This is the hot path when most
        # of a batch is non-core (approx tier); the exact engine's dense
        # case has no scan events at all.
        borders = np.nonzero(core_time > B)[0]
        if len(borders):
            nb, tw = len(borders), slots.shape[1]
            INF3 = np.iinfo(np.int64).max
            cand_id = np.full((nb, tw), INF3, np.int64)
            have = np.zeros((nb, tw), bool)
            flatb = slots[borders].ravel()
            bidx = np.repeat(np.arange(nb), tw)
            tpos = np.tile(np.arange(tw), nb)
            # a slot with no core-candidate members can never answer a
            # scan — drops most fringe buckets in the sampled subclass
            csz = self._core_sizes(self._n_slots)
            keep = csz[flatb] > 0
            flatb, bidx, tpos = flatb[keep], bidx[keep], tpos[keep]
        if len(borders) and len(flatb):
            needed = np.unique(flatb)
            # candidate pool: every final core (batch promotions carry
            # their event time; pre-batch cores time -1).  Batch points
            # with final support are always in ctime, so the override
            # loop below touches promotion events only.
            rowsE = np.nonzero((support > 0) & (self._ids != -1))[0]
            timesE = np.full(len(rowsE), -1, np.int64)
            for m, ct in ctime.items():
                st = step_of.get(m, -1)
                p = int(np.searchsorted(rowsE, row[m]))
                timesE[p] = ct if ct > st else st
            flatE = self._slots[rowsE].ravel()
            idsR = np.repeat(self._ids[rowsE], tw)
            timesR = np.repeat(timesE, tw)
            inn = np.isin(flatE, needed)
            flatE, idsR, timesR = flatE[inn], idsR[inn], timesR[inn]
        if len(borders) and len(flatb) and len(flatE):
            orderE = np.lexsort((idsR, timesR, flatE))
            fsE, tsE, msE = flatE[orderE], timesR[orderE], idsR[orderE]
            # per-slot running min of candidate id in time order, with no
            # per-segment loop: stagger segments by a large DECREASING
            # offset so a global min-accumulate can never carry a value
            # across a segment boundary (earlier segments sit strictly
            # above later ones), then subtract the offsets back out
            seg = np.cumsum(np.concatenate([[0], np.diff(fsE) != 0]))
            base = np.int64(msE.min())
            big = np.int64(msE.max()) - base + 1
            off = (np.int64(seg[-1]) - seg) * big
            pmin = np.minimum.accumulate(msE - base + off) - off + base
            # one composite-key search answers every (border, slot)
            # query: entries < slot*C + (j+1) in the lexsorted pool are
            # exactly this slot's candidates with time < j
            C = np.int64(B + 2)
            ckey = fsE.astype(np.int64) * C + (tsE + 1)
            qstart = np.searchsorted(fsE, flatb, side="left")
            pos = np.searchsorted(
                ckey, flatb.astype(np.int64) * C + (borders[bidx] + 1),
                side="left")
            q = pos > qstart
            bi, ti = bidx[q], tpos[q]
            have[bi, ti] = True
            cand_id[bi, ti] = pmin[pos[q] - 1]
            hit = have.any(axis=1)
            first = have.argmax(axis=1)  # first table in scan order
            for i in np.nonzero(hit)[0]:
                # the scan precedes any later grab
                best[out[borders[i]]] = (-1, int(cand_id[i, first[i]]))
        self.n_scan_events += len(borders)

        # -- apply attachments
        for y, (_, c) in best.items():
            ry = row[y]
            self._attach[ry] = c
            self.anchored.setdefault(c, set()).add(y)
            self._record(y, None, c)
        self.n_grab_events += len(best)

    # ------------------------------------------------------------------ #
    # deletion (sequential mirror of DynamicDBSCAN.delete_point; the
    # accounting is array ops, and no forest repair is ever needed)
    # ------------------------------------------------------------------ #
    def delete_point(self, idx: int) -> None:
        self._delete_one(idx)
        self._comp = None
        self._compact_journal()

    def delete_batch(self, ids: Sequence[int]) -> None:
        """One array pass per batch: departure counts, threshold-crossing
        steps, and the occupancy decrement are computed for the whole
        batch up front (bincount + one stable argsort — the deletion
        mirror of ``add_batch``'s insert pass); the per-point Python work
        that remains is event-scale only (journal records, border
        re-links, demotion cascades), replayed in deletion order so the
        result is bit-identical to the sequential path."""
        check_unique_ids(ids)
        ids = [int(i) for i in ids]
        if len(ids) <= 1 or any(i not in self._row for i in ids):
            # tiny batches gain nothing from the array pass; a missing id
            # keeps the sequential partial-prefix KeyError semantics
            for i in ids:
                self._delete_one(i)
            self._comp = None
            self._compact_journal()
            return
        k, t, D = self.core_k, self.t, len(ids)
        rows_d = np.fromiter((self._row[i] for i in ids), np.int64, D)
        slots_d = self._slots[rows_d]                  # (D, t)
        ns = self._n_slots
        flat_d = slots_d.ravel()
        dep = np.bincount(flat_d, minlength=ns).astype(np.int32)
        smask = self._elig_mask(ids)  # same eligibility as on insert
        if smask is None:
            core_dep, core_flat, rows_map = dep, flat_d, None
        else:
            rows_map = np.nonzero(smask)[0]
            core_flat = slots_d[rows_map].ravel()
            core_dep = np.bincount(core_flat, minlength=ns).astype(np.int32)
        core_old = self._core_sizes(ns).copy()
        core_new_sz = core_old - core_dep
        new_sizes = self._bsize[:ns] - dep

        # threshold down-crossings: the (old - k + 1)-th core-eligible
        # departure drops the slot's core size below k, at that step
        cross_slots = np.nonzero((core_old >= k) & (core_new_sz < k))[0]
        cross_at: Dict[int, List[int]] = {}
        if len(cross_slots):
            order = np.argsort(core_flat, kind="stable")
            sf = core_flat[order]
            starts = np.searchsorted(sf, cross_slots)
            entry = starts + (core_old[cross_slots] - k)
            steps = order[entry] // t
            if rows_map is not None:
                steps = rows_map[steps]
            for s, j in zip(cross_slots, steps):
                cross_at.setdefault(int(j), []).append(int(s))

        self._apply_occupancy_delta(dep, core_dep, ns)

        # replay the sequential deletion events in batch order.  Border
        # re-links are DEFERRED to one pass at the end: a disturbed
        # border's sequential anchor is the min candidate, in the first
        # table holding any, at its LAST re-link — and since candidate
        # sets only shrink during a delete batch (no inserts, demotions
        # only) while the chosen anchor by definition survives, that
        # equals the min live core at batch end.  The sequential path's
        # intermediate hops (re-anchor to a core deleted later in the
        # batch, cascading more re-links) net out of the compacted
        # journal, so state and delta feed are both bit-identical.
        pending: Set[int] = set()
        for j, idx in enumerate(ids):
            row = self._row[idx]
            self._record(idx, self._attach_handle(idx), None)
            if self._support[row] > 0:
                for y in self.anchored.pop(idx, ()):
                    self._attach[self._row[y]] = -1
                    self._record(y, idx, None)
                    pending.add(y)
            else:
                a = int(self._attach[row])
                if a >= 0:
                    self.anchored[a].discard(idx)
            for i in range(t):
                self._member_discard(int(slots_d[j, i]), idx)
            demoted: List[int] = []
            for s in cross_at.get(j, ()):
                for y in self._core_members(s):
                    if not self._core_candidate(y):
                        continue
                    ry = self._row[y]
                    self._support[ry] -= 1
                    if self._support[ry] == 0:
                        demoted.append(y)
            for c in sorted(demoted):
                for y in self.anchored.pop(c, ()):
                    self._attach[self._row[y]] = -1
                    self._record(y, c, None)
                    pending.add(y)
                self._record(c, c, None)
                pending.add(c)
            self.n_demotions += len(demoted)
            self._ids[row] = -1
            self._support[row] = 0
            self._attach[row] = -1
            self._free_rows.append(row)
            del self._row[idx]

        # end-of-batch re-link: min live core per slot, computed once per
        # slot and shared across every disturbed border (the sequential
        # cascade touches the same blob buckets over and over)
        slot_best: Dict[int, int] = {}
        for y in pending:
            ry = self._row.get(y)
            if ry is None:  # disturbed, then deleted later in the batch
                continue
            for i in range(t):
                s = int(self._slots[ry, i])
                c = slot_best.get(s, -2)
                if c == -2:
                    c = min((m for m in self._core_members(s)
                             if self._support[self._row[m]] > 0),
                            default=-1)
                    slot_best[s] = c
                if c >= 0:
                    self._attach[ry] = c
                    self.anchored.setdefault(c, set()).add(y)
                    self._record(y, None, c)
                    break

        # emptied slots free once, at the end (their member sets emptied
        # exactly when the final size reached zero)
        for s in np.nonzero((dep > 0) & (new_sizes == 0))[0]:
            self._free_slot(int(s))
        self._comp = None
        self._compact_journal()

    def _apply_occupancy_delta(self, dep: np.ndarray, core_dep: np.ndarray,
                               ns: int) -> None:
        """Batched occupancy decrement (delete mirror of _batch_stats)."""
        self._bsize[:ns] -= dep

    def _delete_one(self, idx: int) -> None:
        if idx not in self._row:
            raise KeyError(idx)
        row = self._row[idx]
        self._record(idx, self._attach_handle(idx), None)

        unchained: Set[int] = {idx}
        if self._support[row] > 0:
            # chains lose idx first; its borders re-scan against the rest
            for y in list(self.anchored.pop(idx, ())):
                self._attach[self._row[y]] = -1
                self._record(y, idx, None)
                self._relink(y, (), unchained)
        else:
            a = int(self._attach[row])
            if a >= 0:
                self.anchored[a].discard(idx)

        demoted: List[int] = []
        for i in range(self.t):
            s = int(self._slots[row, i])
            self._member_discard(s, idx)
            if self._bucket_shrink(s, idx):
                # bucket drops below threshold: members lose support
                for y in self._core_members(s):
                    if not self._core_candidate(y):
                        continue
                    ry = self._row[y]
                    self._support[ry] -= 1
                    if self._support[ry] == 0:
                        demoted.append(y)
            if self._bsize[s] == 0:
                self._free_slot(s)

        demoted_set = set(demoted)
        for c in sorted(demoted):
            # c leaves the chains, then its borders re-scan, then c itself
            unchained.add(c)
            for y in list(self.anchored.pop(c, ())):
                self._attach[self._row[y]] = -1
                self._record(y, c, None)
                self._relink(y, demoted_set, unchained)
            self._record(c, c, None)
            self._relink(c, demoted_set, unchained)
        self.n_demotions += len(demoted)

        self._ids[row] = -1
        self._support[row] = 0
        self._attach[row] = -1
        self._free_rows.append(row)
        del self._row[idx]

    def _bucket_shrink(self, s: int, idx: int) -> bool:
        """Remove one occupant from slot ``s``; True when the removal
        dropped the slot's support-driving size below the threshold."""
        self._bsize[s] -= 1
        return self._bsize[s] == self.core_k - 1

    def _relink(self, y: int, demoted_set: Set[int],
                unchained: Set[int]) -> None:
        """LinkNonCorePoint against the *chained* set: current cores plus
        still-chained demoted points (the sequential engine removes a
        demoted core's chain entries only when its turn comes, so earlier
        re-links can legally anchor to it; the later unlink re-scans)."""
        ry = self._row[y]
        for i in range(self.t):
            s = int(self._slots[ry, i])
            cands = [m for m in self._core_members(s)
                     if m != y and m not in unchained
                     and (self._support[self._row[m]] > 0
                          or m in demoted_set)]
            if cands:
                c = min(cands)
                self._attach[ry] = c
                self.anchored.setdefault(c, set()).add(y)
                self._record(y, None, c)
                return

    # ------------------------------------------------------------------ #
    # queries
    # ------------------------------------------------------------------ #
    def is_core(self, idx: int) -> bool:
        return self._support[self._row[idx]] > 0

    def core_set(self) -> Set[int]:
        return {i for i, r in self._row.items() if self._support[r] > 0}

    def core_anchor(self, idx: int) -> Optional[int]:
        r = self._row[idx]
        if self._support[r] > 0:
            return idx
        a = int(self._attach[r])
        return a if a >= 0 else None

    def _ensure_comp(self) -> np.ndarray:
        if self._comp is not None:
            return self._comp
        rows = np.fromiter(self._row.values(), np.int64, len(self._row))
        core_rows = rows[self._support[rows] > 0]
        a = b = np.zeros(0, np.int64)
        if len(core_rows):
            S = self._slots[core_rows]                    # (m, t)
            flat = S.ravel()
            rep = np.repeat(core_rows, self.t)
            order = np.argsort(flat, kind="stable")
            sf, rf = flat[order], rep[order]
            same = sf[1:] == sf[:-1]
            a, b = rf[:-1][same], rf[1:][same]
        parent = _sv_components(self._top, a, b)
        comp = np.full(self._cap, -1, np.int64)
        if len(core_rows):
            comp[core_rows] = self._ids[parent[core_rows]]
        self._comp = comp
        self.n_epoch_rebuilds += 1
        if self.obs.enabled:
            self.obs.histogram("engine.cc_edges").observe(len(a))
        return comp

    def get_cluster(self, idx: int):
        """Component handle: the id of the component's representative core
        for cores and attached borders, the point's own id for noise."""
        r = self._row[idx]  # KeyError on dead ids, like forest.root
        if self._support[r] > 0:
            return int(self._ensure_comp()[r])
        a = int(self._attach[r])
        if a < 0:
            return int(idx)
        return int(self._ensure_comp()[self._row[a]])

    component_of = get_cluster

    def labels(self, ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        """Canonical labels; noise -> NOISE.  Components are numbered by
        first occurrence in ``ids`` order (noise singletons consume a
        number before the NOISE overwrite), matching ``DynamicDBSCAN``.

        Note: with an explicit ``ids`` subset, components are the *global*
        components restricted to the subset — the dict engines label the
        forest subgraph instead, which can split a component whose
        connecting cores were excluded.  Full ``labels()`` is identical.
        """
        id_list = list(self._row.keys()) if ids is None else list(ids)
        comp = self._ensure_comp()
        out: Dict[int, int] = {}
        relabel: Dict[int, int] = {}
        for v in id_list:
            r = self._row[v]
            if self._support[r] > 0:
                h = int(comp[r])
                noise = False
            else:
                a = int(self._attach[r])
                noise = a < 0
                h = int(v) if noise else int(comp[self._row[a]])
            num = relabel.setdefault(h, len(relabel))
            out[v] = NOISE if noise else num
        return out

    # ------------------------------------------------------------------ #
    # change feed (same contract as DynamicDBSCAN)
    # ------------------------------------------------------------------ #
    def _record(self, idx: int, old: Optional[int],
                new: Optional[int]) -> None:
        if self._journal is not None:
            self._journal.append((idx, old, new))

    def _attach_handle(self, idx: int) -> Optional[int]:
        r = self._row[idx]
        if self._support[r] > 0:
            return idx
        a = int(self._attach[r])
        return a if a >= 0 else None

    def _compact_journal(self) -> None:
        if not self._journal:
            return
        merged: Dict[int, List[Optional[int]]] = {}
        for idx, old, new in self._journal:
            if idx in merged:
                merged[idx][1] = new
            else:
                merged[idx] = [old, new]
        self._journal = [(i, o, n) for i, (o, n) in merged.items() if o != n]

    def drain_deltas(self) -> List[Tuple[int, Optional[int], Optional[int]]]:
        if self._journal is None:
            self._journal = []
            return []
        self._compact_journal()
        out, self._journal = self._journal, []
        return out

    # ------------------------------------------------------------------ #
    # checkpointable state (dynamic-compatible schema)
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, np.ndarray]:
        ids = sorted(self._row)
        n = len(ids)
        rows = np.fromiter((self._row[i] for i in ids), np.int64, n)
        keys = (np.ascontiguousarray(self._keys32[rows])
                .view(np.uint8).reshape(n, self.t, _KEY_W)
                if n else np.zeros((0, self.t, 0), np.uint8))
        edges = self._edge_list(rows)
        return {
            "ids": np.asarray(ids, dtype=np.int64),
            "points": self._pts[rows].copy(),
            "keys": keys,
            "support": self._support[rows].astype(np.int64),
            "attach": self._attach[rows].copy(),
            "edges": edges,
            "next_idx": np.asarray(self._next_idx, dtype=np.int64),
        }

    def _edge_list(self, rows: np.ndarray) -> np.ndarray:
        """Configuration-canonical spanning edges: consecutive core ids
        per bucket chain plus (border, anchor) edges — the same component
        structure the forest engines persist, minus the history-dependent
        replacement edges."""
        core_rows = rows[self._support[rows] > 0]
        parts = []
        if len(core_rows):
            cid = self._ids[core_rows]
            srt = np.argsort(cid)
            core_rows, cid = core_rows[srt], cid[srt]
            S = self._slots[core_rows]
            flat = S.ravel()
            rep = np.repeat(cid, self.t)
            order = np.argsort(flat, kind="stable")  # id-sorted within slot
            sf, rf = flat[order], rep[order]
            same = sf[1:] == sf[:-1]
            parts.append(np.stack([rf[:-1][same], rf[1:][same]], axis=1))
        att_rows = rows[(self._support[rows] == 0) & (self._attach[rows] >= 0)]
        if len(att_rows):
            parts.append(np.stack(
                [self._ids[att_rows], self._attach[att_rows]], axis=1))
        if not parts:
            return np.zeros((0, 2), np.int64)
        e = np.concatenate(parts).astype(np.int64)
        e = np.stack([e.min(axis=1), e.max(axis=1)], axis=1)
        return np.unique(e, axis=0)

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        if self._row:
            raise ValueError("load_state_dict requires an empty structure")
        ids = [int(i) for i in state["ids"]]
        n = len(ids)
        points = np.asarray(state["points"], dtype=np.float64)
        keys = np.asarray(state["keys"], dtype=np.uint8)
        if n and keys.shape[2] != _KEY_W:
            raise ValueError(
                "soa restores mixed device keys (width 8); got width "
                f"{keys.shape[2]} — snapshot from an exact-key backend")
        support = np.asarray(state["support"], dtype=np.int64)
        attach = np.asarray(state["attach"], dtype=np.int64)
        self._ensure_rows(n)
        rows = np.arange(n, dtype=np.int64)
        self._top = n
        for j, i in enumerate(ids):
            self._row[i] = j
        keys32 = (keys.view(np.int32).reshape(n, self.t, 2)
                  if n else np.zeros((0, self.t, 2), np.int32))
        slots = self._resolve_slots(keys32) if n else np.zeros(
            (0, self.t), np.int32)
        self._ids[rows] = ids
        self._pts[rows] = points
        self._keys32[rows] = keys32
        self._slots[rows] = slots
        self._support[rows] = support
        self._attach[rows] = attach
        if n:
            self._bsize[:self._n_slots] = np.bincount(
                slots.ravel(), minlength=self._n_slots).astype(np.int32)
            self._add_members(slots, ids)
            # stored support must match the restored configuration
            recomputed = self._rebuild_support(slots, ids)
            if not np.array_equal(recomputed, support):
                raise ValueError("snapshot support counts do not match "
                                 "the restored bucket configuration")
        for j, i in enumerate(ids):
            a = int(attach[j])
            if a >= 0:
                self.anchored.setdefault(a, set()).add(i)
        self._next_idx = int(state["next_idx"])
        self._comp = None

    def _rebuild_support(self, slots: np.ndarray,
                         ids: List[int]) -> np.ndarray:
        """Per-point support implied by the restored configuration."""
        return np.add.reduce(self._bsize[slots] >= self.core_k, axis=1)

    # ------------------------------------------------------------------ #
    # invariants (tests)
    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        rows = np.fromiter(self._row.values(), np.int64, len(self._row))
        ids = np.fromiter(self._row.keys(), np.int64, len(self._row))
        if len(rows) == 0:
            assert not self._members  # every bucket freed when it emptied
            return
        core_ids = {int(i) for i, r in zip(ids, rows)
                    if self._support[r] > 0}
        self._check_counts(rows, ids, core_ids)
        # 3. attachment validity: anchor is a live core sharing a bucket;
        #    unattached non-core points see no core in any bucket (noise)
        for i, r in zip(ids, rows):
            i, r = int(i), int(r)
            if self._support[r] > 0:
                assert self._attach[r] == -1
                continue
            a = int(self._attach[r])
            if a >= 0:
                ra = self._row[a]
                assert self._support[ra] > 0, (i, a)
                assert i in self.anchored.get(a, set())
                shared = set(self._slots[r]) & set(self._slots[ra])
                assert shared, (i, a)
            elif self.attach_orphans:
                # with grabs disabled a point promoted *after* y's insert
                # legally coexists with unattached y, so only assert the
                # noise condition when orphan re-attachment is on
                for s in self._slots[r]:
                    # cores are always core-candidates, so the candidate
                    # pool view suffices (and stays valid for the
                    # sampled subclass, which keeps no full membership)
                    mem = self._core_members(int(s))
                    assert not (mem & core_ids) - {i}, (i, int(s))
        # 4. anchored maps mirror attach exactly
        n_anch = sum(len(v) for v in self.anchored.values())
        assert n_anch == int(np.sum(
            (self._support[rows] == 0) & (self._attach[rows] >= 0)))
        # 5. every core pair sharing a bucket shares a component (Thm 2)
        comp = self._ensure_comp()
        for s in list(self._members):
            cs = [m for m in self._core_members(s) if m in core_ids]
            if len(cs) > 1:
                h0 = comp[self._row[cs[0]]]
                assert all(comp[self._row[c]] == h0 for c in cs[1:])

    def _check_counts(self, rows: np.ndarray, ids: np.ndarray,
                      core_ids: Set[int]) -> None:
        # 1. support counts are exact
        occ = self._bsize[self._slots[rows]]
        assert np.array_equal(
            np.add.reduce(occ >= self.core_k, axis=1), self._support[rows])
        # 2. bucket sizes match membership; >=k buckets are all-core
        for s, mem in self._members.items():
            assert self._bsize[s] == len(mem), (s, self._bsize[s], len(mem))
            if len(mem) >= self.core_k:
                assert all(m in core_ids for m in mem)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._row)

    def __contains__(self, idx: int) -> bool:
        return idx in self._row
