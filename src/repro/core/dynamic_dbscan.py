"""DynamicDBSCAN — Algorithm 2 of the paper.

Maintains, under point insertions and deletions:
  * t grid-LSH tables with per-bucket ordered core chains;
  * the exact core set of Definition 4 via per-point *support counts*
    (``support[x] = #{i : |bucket_i(x)| >= k}``; core ⟺ support > 0) —
    this fixes the demotion edge case in the paper's pseudocode, see
    DESIGN.md §3;
  * a spanning forest of the collision graph H in an Euler-Tour-Sequence
    dynamic forest, with per-bucket core *paths* (degree O(t)) and non-core
    points attached with degree ≤ 1.

Per-update cost: O(t·k) bucket/support work on threshold crossings plus
O(t) LINK/CUT/ROOT calls at O(log n) each — the paper's
O(t²·k·(d + log n)) ⇒ O(d log³ n + log⁴ n) with t,k = Θ(log n).

``GetCluster`` is ROOT on the forest: O(log n).
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from ..obs import NULL_OBS
from .buckets import BucketIndex
from .euler_tour import EulerTourForest
from .hashing import GridLSH

NOISE = -1

try:  # optional fast path, resolved once (labels() is per-batch hot)
    import scipy.sparse as _sp
    from scipy.sparse.csgraph import connected_components as _scipy_cc
except ImportError:  # pragma: no cover - exercised via tests monkeypatching
    _sp = None


def claim_index(live, next_idx: int, idx: Optional[int]):
    """Resolve an explicit-or-auto point handle against a live-id set.

    Shared by every engine/adapter so handle assignment is identical
    across backends (the premise of the equivalence tests).  Returns
    ``(idx, new_next_idx)``; raises KeyError on duplicates.
    """
    if idx is None:
        idx = next_idx
    elif idx in live:
        raise KeyError(f"index {idx} already present")
    return idx, max(next_idx, idx + 1)


def check_unique_ids(ids) -> None:
    """Raise KeyError naming the first id appearing twice in ``ids`` —
    the shared ``delete_batch`` precondition (mirrors ``claim_index``'s
    duplicate-pin behavior on the insert side)."""
    seen = set()
    for i in ids:
        if i in seen:
            raise KeyError(f"duplicate id {i} in delete_batch")
        seen.add(i)


def _connected_components(n: int, rows: List[int], cols: List[int]) -> np.ndarray:
    """Component id per position 0..n-1, numbered by first occurrence.

    scipy (when importable) and the pure-Python union-find fallback produce
    identical labellings: both number components in ascending order of
    their smallest member position.
    """
    if _sp is None:
        parent = list(range(n))

        def find(a: int) -> int:
            root = a
            while parent[root] != root:
                root = parent[root]
            while parent[a] != root:  # path compression
                parent[a], a = root, parent[a]
            return root

        for a, b in zip(rows, cols):
            ra, rb = find(a), find(b)
            if ra != rb:
                # union by smaller root id ⇒ each root is its component's
                # minimum, giving first-occurrence numbering below
                if rb < ra:
                    ra, rb = rb, ra
                parent[rb] = ra
        comp = np.empty(n, dtype=np.int64)
        relabel: Dict[int, int] = {}
        for pos in range(n):
            r = find(pos)
            comp[pos] = relabel.setdefault(r, len(relabel))
        return comp
    g = _sp.coo_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, n))
    _, comp = _scipy_cc(g, directed=False)
    return comp


class DynamicDBSCAN:
    def __init__(
        self,
        d: int,
        k: int,
        t: int,
        eps: float,
        seed: int = 0,
        attach_orphans: bool = True,
        lsh: Optional[GridLSH] = None,
        repair: str = "exact",
    ):
        if repair not in ("exact", "paper"):
            raise ValueError(repair)
        # 'exact' restores the Thm-2 spanning-forest invariant with a
        # replacement-edge scan (O(smaller side) on genuine splits);
        # 'paper' is Alg. 2's literal pred/succ-only repair — cheaper, but
        # can strand cores after deletions (DESIGN.md §3).
        self.repair = repair
        self.d, self.k, self.t, self.eps = d, int(k), int(t), float(eps)
        self.lsh = lsh if lsh is not None else GridLSH(d, eps, t, seed)
        if self.lsh.t != self.t or self.lsh.d != d:
            raise ValueError("lsh family incompatible with (d, t)")
        self.attach_orphans = attach_orphans
        self.forest = EulerTourForest(seed=seed)
        self.buckets = BucketIndex(self.t)
        self.points: Dict[int, np.ndarray] = {}
        self.keys: Dict[int, list] = {}       # idx -> [t bucket keys]
        self.support: Dict[int, int] = {}     # idx -> #buckets of size >= k
        self.attach: Dict[int, Optional[int]] = {}   # non-core -> anchor core
        self.anchored: Dict[int, Set[int]] = {}      # core -> anchored set
        self._next_idx = 0
        # change feed: (idx, old, new) attachment deltas, None until a
        # consumer activates it via drain_deltas() (see below)
        self._journal: Optional[List[Tuple[int, Optional[int], Optional[int]]]] = None
        # instrumentation: how often the replacement-edge repair fires
        self.n_repair_scans = 0
        self.n_repair_links = 0
        # observability handle; rebound by the owning adapter when the
        # config's obs knob is on (class default: shared no-op)
        self.obs = NULL_OBS

    # ------------------------------------------------------------------ #
    # public API (paper's procedures)
    # ------------------------------------------------------------------ #
    def add_point(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        """AddPoint(x).  Returns the point's index (stable handle)."""
        idx, self._next_idx = claim_index(self.points, self._next_idx, idx)
        x = np.asarray(x, dtype=np.float64)
        if x.shape != (self.d,):
            raise ValueError(f"point shape {x.shape} != ({self.d},)")
        keys = self.lsh.keys(x)
        return self._add_with_keys(x, keys, idx)

    def _add_with_keys(self, x: np.ndarray, keys: list, idx: int) -> int:
        self.points[idx] = x
        self.keys[idx] = keys
        self.support[idx] = 0
        self.attach[idx] = None
        self.forest.add_node(idx)

        promoted: Set[int] = set()  # the paper's C'
        for i, key in enumerate(keys):
            b = self.buckets.get_or_create(i, key)
            b.members.add(idx)
            sz = len(b.members)
            if sz == self.k:
                # bucket crosses the threshold: every member gains support
                for y in b.members:
                    self.support[y] += 1
                    if self.support[y] == 1:
                        promoted.add(y)
            elif sz > self.k:
                self.support[idx] += 1
                if self.support[idx] == 1:
                    promoted.add(idx)

        for c in sorted(promoted):  # idx order keeps chains coherent
            self._link_core_point(c)
        if self.support[idx] == 0:
            # journal: _anchor records the attach; noise inserts are a
            # no-op delta (None -> None) by the handle contract
            self._link_non_core_point(idx)
        return idx

    def delete_point(self, idx: int) -> None:
        """DeletePoint(x)."""
        if idx not in self.points:
            raise KeyError(idx)
        if self._journal is not None:
            self._record(idx, self._attach_handle(idx), None)
        if self.support[idx] > 0:
            self._unlink_core_point(idx)  # path repair + anchored re-link
        else:
            anchor = self.attach[idx]
            if anchor is not None:
                self.forest.cut(idx, anchor)
                self.anchored[anchor].discard(idx)

        demoted: List[int] = []
        for i, key in enumerate(self.keys[idx]):
            b = self.buckets.get(i, key)
            b.members.discard(idx)
            sz = len(b.members)
            if sz == self.k - 1:
                # bucket drops below threshold: remaining members lose support
                for y in b.members:
                    self.support[y] -= 1
                    if self.support[y] == 0:
                        demoted.append(y)
            self.buckets.drop_if_empty(i, key)

        for c in sorted(demoted):
            self._unlink_core_point(c)
            self._record(c, c, None)  # demotion; _anchor records re-attach
            self._link_non_core_point(c)

        self.forest.remove_node(idx)
        for m in (self.points, self.keys, self.support, self.attach):
            del m[idx]
        self.anchored.pop(idx, None)

    def get_cluster(self, idx: int):
        """GetCluster(x): unique id of x's cluster — ROOT on the forest."""
        return self.forest.root(idx)

    def is_core(self, idx: int) -> bool:
        return self.support[idx] > 0

    def core_set(self) -> Set[int]:
        return {i for i, s in self.support.items() if s > 0}

    # component_of is the documented name of the native point query on the
    # repro.api protocol; for this engine it is exactly GetCluster (ROOT).
    component_of = get_cluster

    def core_anchor(self, idx: int) -> Optional[int]:
        """The core point ``idx``'s cluster membership rides on: itself if
        core, its anchor if an attached border point, None if noise.
        O(1) — the native query the sharded hot path resolves through."""
        if self.support[idx] > 0:
            return idx
        return self.attach[idx]

    # ------------------------------------------------------------------ #
    # change feed: (idx, old, new) attachment deltas per update batch
    # ------------------------------------------------------------------ #
    def _record(self, idx: int, old: Optional[int], new: Optional[int]) -> None:
        if self._journal is not None:
            self._journal.append((idx, old, new))

    def _attach_handle(self, idx: int) -> Optional[int]:
        return idx if self.support[idx] > 0 else self.attach[idx]

    def _compact_journal(self) -> None:
        """Squash the pending feed to one (first-old, last-new) entry per
        id, dropping no-ops — keeps the feed O(touched ids), not O(ops)."""
        if not self._journal:
            return
        merged: Dict[int, List[Optional[int]]] = {}
        for idx, old, new in self._journal:
            if idx in merged:
                merged[idx][1] = new
            else:
                merged[idx] = [old, new]
        self._journal = [(i, o, n) for i, (o, n) in merged.items() if o != n]

    def drain_deltas(self) -> List[Tuple[int, Optional[int], Optional[int]]]:
        """Return and clear the attachment deltas since the last drain.

        Entries are ``(idx, old, new)`` where a handle is the point itself
        (core), its anchor core (attached border), or None (noise / not
        present); consecutive changes to one id are compacted.  The first
        call activates tracking (and returns []): the journal costs nothing
        until someone consumes it.
        """
        if self._journal is None:
            self._journal = []
            return []
        self._compact_journal()
        out, self._journal = self._journal, []
        return out

    # ------------------------------------------------------------------ #
    # bulk label extraction (for evaluation after each batch)
    # ------------------------------------------------------------------ #
    def labels(self, ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        """Cluster labels; noise (unattached non-core) -> NOISE.

        Uses one connected-components pass over the forest's edge list
        (O(n α(n))) instead of n ROOT queries; identical partition.
        scipy's C-speed ``connected_components`` is used when importable;
        otherwise a pure-Python union-find with the same labelling
        (components numbered by first occurrence in ``ids`` order).
        """
        ids = list(self.points.keys()) if ids is None else list(ids)
        id_to_pos = {v: i for i, v in enumerate(ids)}
        rows, cols = [], []
        seen = set()
        for (u, v) in self.forest._edge.keys():
            if (v, u) in seen:
                continue
            seen.add((u, v))
            if u in id_to_pos and v in id_to_pos:
                rows.append(id_to_pos[u])
                cols.append(id_to_pos[v])
        comp = _connected_components(len(ids), rows, cols)
        out: Dict[int, int] = {}
        for v, pos in id_to_pos.items():
            if self.support[v] == 0 and self.attach[v] is None:
                out[v] = NOISE
            else:
                out[v] = int(comp[pos])
        return out

    # ------------------------------------------------------------------ #
    # checkpointable state (used by repro.api snapshot/restore)
    # ------------------------------------------------------------------ #
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Full structural state as fixed-dtype arrays (npz-serialisable).

        Bucket keys are raw bytes of constant width (exact codes: 8·d;
        mixed device keys: 8), stored as a uint8 tensor.  Forest edges are
        stored explicitly so ``load_state_dict`` restores the *exact*
        spanning forest — border-point anchors are history-dependent, so a
        replay-based restore could legally land them in another cluster.
        """
        ids = sorted(self.points)
        n = len(ids)
        d = self.d
        points = np.zeros((n, d), dtype=np.float64)
        support = np.zeros(n, dtype=np.int64)
        attach = np.full(n, -1, dtype=np.int64)
        keylen = len(self.keys[ids[0]][0]) if n else 0
        keys = np.zeros((n, self.t, keylen), dtype=np.uint8)
        for j, i in enumerate(ids):
            points[j] = self.points[i]
            support[j] = self.support[i]
            if self.attach[i] is not None:
                attach[j] = self.attach[i]
            for ti, key in enumerate(self.keys[i]):
                keys[j, ti] = np.frombuffer(key, dtype=np.uint8)
        edges = sorted(
            (u, v) for (u, v) in self.forest._edge if u < v
        )
        return {
            "ids": np.asarray(ids, dtype=np.int64),
            "points": points,
            "keys": keys,
            "support": support,
            "attach": attach,
            "edges": np.asarray(edges, dtype=np.int64).reshape(-1, 2),
            "next_idx": np.asarray(self._next_idx, dtype=np.int64),
        }

    def load_state_dict(self, state: Dict[str, np.ndarray]) -> None:
        """Restore a :meth:`state_dict` into this (empty) instance."""
        if self.points:
            raise ValueError("load_state_dict requires an empty structure")
        ids = [int(i) for i in state["ids"]]
        points = np.asarray(state["points"], dtype=np.float64)
        keys = np.asarray(state["keys"], dtype=np.uint8)
        support = np.asarray(state["support"], dtype=np.int64)
        attach = np.asarray(state["attach"], dtype=np.int64)
        for j, i in enumerate(ids):
            self.points[i] = points[j]
            self.keys[i] = [keys[j, ti].tobytes() for ti in range(self.t)]
            self.support[i] = int(support[j])
            self.attach[i] = int(attach[j]) if attach[j] >= 0 else None
            self.forest.add_node(i)
            for ti, key in enumerate(self.keys[i]):
                b = self.buckets.get_or_create(ti, key)
                b.members.add(i)
                if support[j] > 0:
                    b.add_core(i)
        for i in ids:
            a = self.attach[i]
            if a is not None:
                self.anchored.setdefault(a, set()).add(i)
        for u, v in np.asarray(state["edges"], dtype=np.int64).reshape(-1, 2):
            if not self.forest.link(int(u), int(v)):
                raise ValueError(f"edge ({u}, {v}) does not extend a forest")
        self._next_idx = int(state["next_idx"])

    # ------------------------------------------------------------------ #
    # internal: Alg. 2 subroutines
    # ------------------------------------------------------------------ #
    def _link_core_point(self, c: int) -> None:
        """LinkCorePoint: splice c into every bucket's core chain."""
        if self._journal is not None:
            self._record(c, self.attach[c], c)  # promotion: c is now core
        # cut any edge incident to c (non-core c had at most its anchor)
        anchor = self.attach[c]
        if anchor is not None:
            self.forest.cut(c, anchor)
            self.anchored[anchor].discard(c)
            self.attach[c] = None

        for i, key in enumerate(self.keys[c]):
            b = self.buckets.get(i, key)
            c1, c2 = b.core_neighbors(c)
            b.add_core(c)
            if c1 is not None and c2 is not None:
                self.forest.cut(c1, c2)
            if c1 is not None:
                self.forest.link(c1, c)
            if c2 is not None:
                self.forest.link(c, c2)
            # orphan re-attachment (DESIGN.md §3.2): only sub-threshold
            # buckets can contain non-core members, so this scan is O(k).
            if self.attach_orphans and len(b.members) < self.k:
                for y in b.members:
                    if y != c and self.support[y] == 0 and self.attach[y] is None:
                        self._anchor(y, c)

    def _unlink_core_point(self, c: int) -> None:
        """UnlinkCorePoint: remove c from every chain, repairing paths.

        The paper's repair (LINK the pred/succ pair per bucket) is not
        sufficient on its own: cycle-avoided chain links mean a bucket's
        connectivity may route through ``c`` via *another* bucket's edge,
        stranding cores the local repair never touches (DESIGN.md §3.4).
        We therefore collect every vertex whose tree may have changed and
        run a replacement-edge scan over the split-off components —
        H-edges are recoverable from the bucket chains, so this restores
        the exact spanning-forest invariant (Thm 2) at a cost proportional
        to the smaller side, and is free when nothing actually split.
        """
        touched: List[int] = []
        for i, key in enumerate(self.keys[c]):
            b = self.buckets.get(i, key)
            c1, c2 = b.core_neighbors(c)
            b.remove_core(c)
            if c1 is not None:
                self.forest.cut(c1, c)
                touched.append(c1)
            if c2 is not None:
                self.forest.cut(c, c2)
                touched.append(c2)
            if c1 is not None and c2 is not None:
                self.forest.link(c1, c2)
        # re-link any non-core points attached to c
        for y in list(self.anchored.get(c, ())):
            self.forest.cut(y, c)
            self.anchored[c].discard(y)
            self.attach[y] = None
            self._record(y, c, None)  # detach; _anchor records a re-attach
            self._link_non_core_point(y)
            touched.append(y)
        self._repair_components(touched)

    # ------------------------------------------------------------------ #
    # replacement-edge repair (correctness fix over the paper's pseudocode)
    # ------------------------------------------------------------------ #
    def _repair_components(self, touched: List[int]) -> None:
        """Re-merge split-off components that H still connects.

        Every component created by the cuts contains one of ``touched``.
        For all but the largest such component, scan each core member's
        buckets and LINK it to its chain pred/succ — this covers every
        consecutive-core H-pair with an endpoint in a scanned component,
        which is exactly the set of possibly-stranded pairs.
        """
        if self.repair == "paper":
            return
        comps = {}
        for v in touched:
            if v in self.points:
                comps.setdefault(self.forest.root(v), v)
        if len(comps) <= 1:
            return
        self.n_repair_scans += 1
        # enumerate components round-robin so total work is bounded by the
        # SMALLER sides: the last iterator standing is the largest
        # component and is never fully materialised.
        iters = {r: self.forest.tree_nodes(v) for r, v in comps.items()}
        collected = {r: [] for r in comps}
        active = set(iters)
        while len(active) > 1:
            for r in list(active):
                try:
                    collected[r].append(next(iters[r]))
                except StopIteration:
                    active.discard(r)
        snapshots = [collected[r] for r in comps if r not in active]
        if self.obs.enabled:
            # repair depth: nodes collected off the smaller sides — the
            # per-delete cost the paper bounds by the splits' small halves
            self.obs.histogram("engine.repair_nodes").observe(
                sum(len(snap) for snap in snapshots))
        for snap in snapshots:
            for w in snap:
                if self.support.get(w, 0) == 0:
                    continue
                for j, key in enumerate(self.keys[w]):
                    b = self.buckets.get(j, key)
                    p, s = b.core_neighbors(w)
                    for cand in (p, s):
                        if cand is not None and self.forest.link(w, cand):
                            self.n_repair_links += 1

    def _link_non_core_point(self, x: int) -> None:
        """LinkNonCorePoint: attach x to one colliding core point, if any."""
        for i, key in enumerate(self.keys[x]):
            b = self.buckets.get(i, key)
            if b is None:
                continue
            c = b.first_core()
            if c is not None and c != x:
                self._anchor(x, c)
                return

    def _anchor(self, y: int, c: int) -> None:
        if self.forest.link(y, c):
            self.attach[y] = c
            self.anchored.setdefault(c, set()).add(y)
            self._record(y, None, c)

    # ------------------------------------------------------------------ #
    # invariant checks (used by tests)
    # ------------------------------------------------------------------ #
    def check_invariants(self) -> None:
        # 1. support counts are exact
        for idx, keys in self.keys.items():
            s = sum(
                1 for i, key in enumerate(keys) if len(self.buckets.get(i, key)) >= self.k
            )
            assert s == self.support[idx], (idx, s, self.support[idx])
        # 2. buckets of size >= k contain only core points; core chains match
        for i, table in enumerate(self.buckets.tables):
            for key, b in table.items():
                cores = sorted(y for y in b.members if self.support[y] > 0)
                assert b.cores == cores, (i, key, b.cores, cores)
                if len(b.members) >= self.k:
                    assert len(cores) == len(b.members)
        # 3. non-core degree <= 1; forest degrees of cores O(t)
        for idx in self.points:
            deg = self.forest.degree(idx)
            if self.support[idx] == 0:
                assert deg <= 1, (idx, deg)
                if self.attach[idx] is not None:
                    assert self.forest.has_edge(idx, self.attach[idx])
            else:
                assert deg <= 2 * self.t + len(self.anchored.get(idx, ())), idx
        # 4. forest edges only touch (core,core) or (core,non-core anchor)
        for (u, v) in self.forest._edge:
            su, sv = self.support[u] > 0, self.support[v] > 0
            assert su or sv, (u, v)
        # 5. every core pair sharing a bucket is in the same tree (Thm 2)
        for i, table in enumerate(self.buckets.tables):
            for key, b in table.items():
                if len(b.cores) > 1:
                    r0 = self.forest.root(b.cores[0])
                    for c in b.cores[1:]:
                        assert self.forest.root(c) == r0
