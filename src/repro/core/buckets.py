"""Hash-bucket index: t tables of buckets with ordered core chains.

Each bucket keeps its member set and the *sorted* list of its current core
points (by insertion index) so the paper's predecessor/successor queries
(Alg. 2 lines 31–32 / 38–39) run in O(log |bucket|).  The sorted container
is an array-backed sorted list (C-speed ``bisect``); a balanced-tree drop-in
would give the same asymptotics with a larger constant — see DESIGN.md §3.
"""

from __future__ import annotations

from bisect import bisect_left, insort
from typing import Dict, List, Optional, Tuple


class Bucket:
    __slots__ = ("members", "cores")

    def __init__(self):
        self.members: set = set()
        self.cores: List[int] = []  # sorted point indices of core members

    def __len__(self) -> int:
        return len(self.members)

    # ---- ordered core-chain queries (paper's c1/c2) -------------------- #
    def core_neighbors(self, idx: int) -> Tuple[Optional[int], Optional[int]]:
        """(pred, succ) core indices around ``idx`` (idx not yet inserted or
        already present; presence is handled by the caller's bisect side)."""
        pos = bisect_left(self.cores, idx)
        pred = self.cores[pos - 1] if pos > 0 else None
        if pos < len(self.cores) and self.cores[pos] == idx:
            succ = self.cores[pos + 1] if pos + 1 < len(self.cores) else None
        else:
            succ = self.cores[pos] if pos < len(self.cores) else None
        return pred, succ

    def add_core(self, idx: int) -> None:
        insort(self.cores, idx)

    def remove_core(self, idx: int) -> None:
        pos = bisect_left(self.cores, idx)
        if pos < len(self.cores) and self.cores[pos] == idx:
            self.cores.pop(pos)

    def first_core(self) -> Optional[int]:
        return self.cores[0] if self.cores else None


class BucketIndex:
    """t hash tables mapping bucket key -> :class:`Bucket`."""

    def __init__(self, t: int):
        self.tables: List[Dict[bytes, Bucket]] = [dict() for _ in range(t)]

    def get(self, table: int, key: bytes) -> Optional[Bucket]:
        return self.tables[table].get(key)

    def get_or_create(self, table: int, key: bytes) -> Bucket:
        b = self.tables[table].get(key)
        if b is None:
            b = Bucket()
            self.tables[table][key] = b
        return b

    def drop_if_empty(self, table: int, key: bytes) -> None:
        b = self.tables[table].get(key)
        if b is not None and not b.members:
            del self.tables[table][key]

    def n_buckets(self) -> int:
        return sum(len(tb) for tb in self.tables)
