"""Sequence skip list supporting split / concat / representative.

This is the data structure Tseng, Dhulipala and Blelloch (ALENEX'19) use to
store Euler Tour Sequences, and the one the paper adopts.  Elements carry no
keys — the structure maintains an *ordering* only, and supports:

  * ``concat(a, b)``        join two sequences (a's first), O(log n) w.h.p.
  * ``split_after(e)``      split the sequence containing ``e`` right after
                            ``e``.
  * ``representative(e)``   canonical element (the sequence head) of the
                            sequence containing ``e``, O(log n) w.h.p.  Two
                            elements are in the same sequence iff their
                            representatives are identical.
  * ``first/last/iter_seq`` for tests and oracles.

Each element owns a tower of (prev, next) links, one pair per level; tower
heights are geometric(p=1/2) drawn from a per-structure RNG so runs are
reproducible.  There are no sentinel heads: a sequence is identified by its
leftmost element, so ``concat``/``split`` never maintain external handles.
Level-``l`` links connect exactly the nodes of height > ``l``.
"""

from __future__ import annotations

import random
from typing import Iterator, List, Optional


class SLNode:
    """One element of a sequence skip list."""

    __slots__ = ("prev", "next", "height", "payload")

    def __init__(self, height: int, payload=None):
        self.height = height
        self.prev: List[Optional["SLNode"]] = [None] * height
        self.next: List[Optional["SLNode"]] = [None] * height
        self.payload = payload

    def __repr__(self):  # pragma: no cover - debug aid
        return f"SLNode({self.payload!r}, h={self.height})"


class SkipListSeq:
    """Sequence skip-list operations (nodes created via :meth:`make_node`)."""

    def __init__(self, seed: int = 0, p: float = 0.5, max_height: int = 48):
        self._rng = random.Random(seed)
        self._p = p
        self._max_height = max_height

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    def make_node(self, payload=None) -> SLNode:
        h = 1
        while h < self._max_height and self._rng.random() < self._p:
            h += 1
        return SLNode(h, payload)

    # ------------------------------------------------------------------ #
    # queries
    # ------------------------------------------------------------------ #
    @staticmethod
    def representative(e: SLNode) -> SLNode:
        """Sequence head (leftmost element), found in O(log n) expected by
        climbing to taller towers while walking left."""
        x = e
        lvl = x.height - 1
        while True:
            p = x.prev[lvl]
            if p is not None:
                x = p
                lvl = x.height - 1  # climb to the new tower's top
                continue
            if lvl == 0:
                return x
            lvl -= 1

    @staticmethod
    def first(e: SLNode) -> SLNode:
        return SkipListSeq.representative(e)

    @staticmethod
    def last(e: SLNode) -> SLNode:
        """Sequence tail, symmetric to :meth:`representative`."""
        x = e
        lvl = x.height - 1
        while True:
            n = x.next[lvl]
            if n is not None:
                x = n
                lvl = x.height - 1
                continue
            if lvl == 0:
                return x
            lvl -= 1

    @staticmethod
    def iter_seq(e: SLNode) -> Iterator[SLNode]:
        x = SkipListSeq.first(e)
        while x is not None:
            yield x
            x = x.next[0]

    @staticmethod
    def same_seq(a: SLNode, b: SLNode) -> bool:
        return SkipListSeq.representative(a) is SkipListSeq.representative(b)

    # ------------------------------------------------------------------ #
    # structural ops
    # ------------------------------------------------------------------ #
    @staticmethod
    def _nearest_left_taller(x: SLNode, lvl: int) -> Optional[SLNode]:
        """Nearest node strictly left of ``x`` with height > ``lvl``.

        Precondition: every node strictly between the result and ``x`` has
        height <= max(x.height, lvl).  Walks top-level prev links, which
        connect nodes of non-decreasing reachable height.
        """
        y = x.prev[x.height - 1]
        while y is not None and y.height <= lvl:
            y = y.prev[y.height - 1]
        return y

    @staticmethod
    def _nearest_right_taller(x: SLNode, lvl: int) -> Optional[SLNode]:
        y = x.next[x.height - 1]
        while y is not None and y.height <= lvl:
            y = y.next[y.height - 1]
        return y

    @staticmethod
    def split_after(e: SLNode) -> None:
        """Split the sequence containing ``e`` into [..e] and [e.next ..].

        No-op if ``e`` is the last element.  For each level ``l`` the single
        boundary-crossing link leaves the rightmost node at-or-before ``e``
        of height > ``l``; we find those nodes by climbing left from ``e``.
        """
        if e.next[0] is None:
            return
        x = e
        lvl = 0
        while True:
            while lvl < x.height:
                nxt = x.next[lvl]
                if nxt is not None:
                    x.next[lvl] = None
                    nxt.prev[lvl] = None
                lvl += 1
            y = SkipListSeq._nearest_left_taller(x, lvl)
            if y is None:
                return
            x = y

    @staticmethod
    def concat(a_any: SLNode, b_any: SLNode) -> None:
        """Concatenate the sequences containing ``a_any`` (first) and
        ``b_any`` (second).  Caller guarantees they are distinct sequences.
        """
        # rights[l]: last node of A with height > l; lefts[l]: first of B.
        ra = SkipListSeq._boundary(SkipListSeq.last(a_any), left_side=True)
        lb = SkipListSeq._boundary(SkipListSeq.first(b_any), left_side=False)
        for lvl in range(min(len(ra), len(lb))):
            ra[lvl].next[lvl] = lb[lvl]
            lb[lvl].prev[lvl] = ra[lvl]

    @staticmethod
    def _boundary(x: SLNode, left_side: bool) -> List[SLNode]:
        """Per-level boundary nodes starting from a sequence end.

        ``left_side=True``: x is the tail of A; out[l] = last node of A at
        level l.  ``left_side=False``: x is the head of B; out[l] = first
        node of B at level l.
        """
        out: List[SLNode] = []
        lvl = 0
        while True:
            while lvl < x.height:
                out.append(x)
                lvl += 1
            y = (
                SkipListSeq._nearest_left_taller(x, lvl)
                if left_side
                else SkipListSeq._nearest_right_taller(x, lvl)
            )
            if y is None:
                return out
            x = y
