# The paper's primary contribution: dynamic DBSCAN over an Euler-Tour
# dynamic forest, plus the static baselines it is evaluated against.
from .dynamic_dbscan import DynamicDBSCAN, NOISE  # noqa: F401
from .euler_tour import EulerTourForest  # noqa: F401
from .fixed_core import EMZFixedCore  # noqa: F401
from .hashing import GridLSH  # noqa: F401
from .metrics import adjusted_rand_index, normalized_mutual_info  # noqa: F401
from .naive_dbscan import SklearnStyleDBSCAN, dbscan  # noqa: F401
from .skiplist import SkipListSeq  # noqa: F401
from .static_emz import EMZRecompute, emz_cluster  # noqa: F401
from .batched import BatchedDynamicDBSCAN  # noqa: F401
from .soa import SoADynamicDBSCAN  # noqa: F401
