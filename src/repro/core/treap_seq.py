"""Treap-backed sequence (Henzinger–King-style balanced-BST alternative to
the skip list) with the same split/concat/representative interface.

The paper follows Tseng et al.'s skip lists; Henzinger & King's original
formulation used balanced binary trees — this backend exists to compare the
two (benchmarks) and as a drop-in for ``EulerTourForest`` via duck typing:
``representative`` here is the treap root (found by climbing parent
pointers, O(log n) expected).
"""

from __future__ import annotations

import random
from typing import Iterator, Optional


class TreapNode:
    __slots__ = ("left", "right", "parent", "prio", "payload")

    def __init__(self, prio: float, payload=None):
        self.left: Optional["TreapNode"] = None
        self.right: Optional["TreapNode"] = None
        self.parent: Optional["TreapNode"] = None
        self.prio = prio
        self.payload = payload


def _root(e: TreapNode) -> TreapNode:
    while e.parent is not None:
        e = e.parent
    return e


def _leftmost(t: Optional[TreapNode]) -> Optional[TreapNode]:
    if t is None:
        return None
    while t.left is not None:
        t = t.left
    return t


def _merge(a: Optional[TreapNode], b: Optional[TreapNode]) -> Optional[TreapNode]:
    if a is None:
        return b
    if b is None:
        return a
    if a.prio < b.prio:
        r = _merge(a.right, b)
        a.right = r
        if r is not None:
            r.parent = a
        a.parent = None
        return a
    r = _merge(a, b.left)
    b.left = r
    if r is not None:
        r.parent = b
    b.parent = None
    return b


def _detach(child: Optional[TreapNode]) -> Optional[TreapNode]:
    if child is not None:
        child.parent = None
    return child


def _split_after_node(e: TreapNode):
    """Split the treap containing e into ([..e], [e+1..]); returns roots."""
    # capture the ancestor path BEFORE any merge (merging can give e a new
    # parent inside the left piece)
    path = []
    cur = e
    while cur.parent is not None:
        p = cur.parent
        path.append((p, p.left is cur))
        cur.parent = None
        cur = p
    left = _detach(e.left)
    e.left = None
    rhs = _detach(e.right)
    e.right = None
    lhs = _merge(left, e)
    for p, came_left in path:
        if came_left:
            # p and p's right subtree come after e
            p.left = None
            rt = _detach(p.right)
            p.right = None
            rhs = _merge(rhs, _merge(p, rt))
        else:
            # p's left subtree and p come before e's piece
            p.right = None
            lt = _detach(p.left)
            p.left = None
            lhs = _merge(_merge(lt, p), lhs)
    return lhs, rhs


class TreapSeq:
    """Same interface as SkipListSeq (make_node + static ops)."""

    def __init__(self, seed: int = 0, **_):
        self._rng = random.Random(seed)

    def make_node(self, payload=None) -> TreapNode:
        return TreapNode(self._rng.random(), payload)

    @staticmethod
    def representative(e: TreapNode) -> TreapNode:
        return _root(e)

    @staticmethod
    def same_seq(a: TreapNode, b: TreapNode) -> bool:
        return _root(a) is _root(b)

    @staticmethod
    def first(e: TreapNode) -> TreapNode:
        return _leftmost(_root(e))

    @staticmethod
    def last(e: TreapNode) -> TreapNode:
        t = _root(e)
        while t.right is not None:
            t = t.right
        return t

    @staticmethod
    def iter_seq(e: TreapNode) -> Iterator[TreapNode]:
        stack = []
        node = _root(e)
        while stack or node is not None:
            while node is not None:
                stack.append(node)
                node = node.left
            node = stack.pop()
            yield node
            node = node.right

    @staticmethod
    def split_after(e: TreapNode) -> None:
        _split_after_node(e)

    @staticmethod
    def concat(a_any: TreapNode, b_any: TreapNode) -> None:
        _merge(_root(a_any), _root(b_any))
