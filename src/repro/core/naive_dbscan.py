"""Exact DBSCAN — Algorithm 1 of the paper (Ester et al. 1996 variant).

A point is core iff at least k points (itself included) lie within its
eps-ball; the cluster graph connects every core point to everything in its
eps-ball; clusters are connected components; non-core points with no core
neighbour are noise.

The neighbour counting / adjacency construction is the O(n² d) hot spot —
on TPU it runs through the blocked Pallas kernel
(``repro.kernels.pairwise_dist``); this host implementation uses the same
blocking so memory stays O(n·B).
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import connected_components

from .dynamic_dbscan import NOISE


def eps_neighbor_counts(X: np.ndarray, eps: float, block: int = 2048) -> np.ndarray:
    """|B(x, eps)| per point, computed in row blocks."""
    X = np.asarray(X, dtype=np.float64)
    n = X.shape[0]
    sq = np.einsum("ij,ij->i", X, X)
    counts = np.zeros(n, dtype=np.int64)
    e2 = eps * eps
    for s in range(0, n, block):
        e = min(s + block, n)
        d2 = sq[s:e, None] + sq[None, :] - 2.0 * (X[s:e] @ X.T)
        counts[s:e] = (d2 <= e2 + 1e-9).sum(axis=1)
    return counts


def dbscan(X: np.ndarray, k: int, eps: float, block: int = 2048) -> np.ndarray:
    """Exact Algorithm-1 DBSCAN; returns labels with noise = -1."""
    X = np.asarray(X, dtype=np.float64)
    n = X.shape[0]
    counts = eps_neighbor_counts(X, eps, block)
    core = counts >= k
    sq = np.einsum("ij,ij->i", X, X)
    e2 = eps * eps
    rows, cols = [], []
    core_idx = np.flatnonzero(core)
    for s in range(0, len(core_idx), block):
        ci = core_idx[s : s + block]
        d2 = sq[ci, None] + sq[None, :] - 2.0 * (X[ci] @ X.T)
        r, c = np.nonzero(d2 <= e2 + 1e-9)
        rows.append(ci[r])
        cols.append(c)
    rows = np.concatenate(rows) if rows else np.empty(0, dtype=np.int64)
    cols = np.concatenate(cols) if cols else np.empty(0, dtype=np.int64)
    g = sp.coo_matrix((np.ones(len(rows)), (rows, cols)), shape=(n, n))
    _, comp = connected_components(g, directed=False)
    labels = comp.astype(np.int64)
    # points not adjacent to any core point are noise
    touched = np.zeros(n, dtype=bool)
    touched[np.unique(cols)] = True
    touched[core] = True
    labels[~touched] = NOISE
    return labels


class SklearnStyleDBSCAN:
    """Streaming wrapper matching the paper's SKLEARN baseline: full exact
    recluster after every batch."""

    def __init__(self, k: int, eps: float):
        self.k, self.eps = k, eps
        self._X: list = []

    def add_batch(self, Xb: np.ndarray) -> np.ndarray:
        self._X.append(np.asarray(Xb, dtype=np.float64))
        X = np.concatenate(self._X, axis=0)
        return dbscan(X, self.k, self.eps)
