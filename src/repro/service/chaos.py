"""Fault-injection harness for the shard transports.

:class:`ChaosClient` wraps any :class:`~repro.service.transport.ShardClient`
and injects a failure at the Nth request it sees (optionally repeating),
so fault-tolerance tests drive the *real* recovery machinery instead of
mocking it:

  * ``"drop"`` — the request is swallowed and
    :class:`~repro.service.transport.ShardUnavailableError` raised, as if
    the transport had burned its whole retry budget.  Exercises the
    coordinator's failover/rollback paths.
  * ``"delay"`` — ``delay_s`` of added latency before the request is
    forwarded.  Exercises deadlines, stragglers detectors, and the
    heartbeat registry.
  * ``"close"`` — the wrapped transport's live socket is closed just
    before the request goes out.  A reconnecting transport (tcp) must
    retry, re-handshake, and dedup; a single-socket transport (process)
    surfaces ShardUnavailableError.  Exercises the retry + exactly-once
    machinery end to end.
  * ``"corrupt"`` — the request's encoded frame is bit-flipped before it
    is written (framing stays intact, the payload is garbage).  The
    worker must answer with an error frame and keep serving — a corrupt
    frame never kills a shard.

The server-side counterpart is the worker's ``--die-after N`` flag
(:mod:`repro.service.worker`), which hard-exits the shard process upon
receiving its Nth request — a real crash, observed by the client as a
mid-request EOF.

The wrapper is transparent when idle: requests forward unchanged, wire
counters mirror the wrapped client's, and typed methods are inherited
from the ShardClient base (they all funnel through ``request``).
"""

from __future__ import annotations

import time
from typing import FrozenSet, Optional

import numpy as np

from ..obs import NULL_OBS, Obs
from . import messages as m
from .codec import decode, encode, read_frame, write_frame
from . import service as _service
from .transport import ShardClient, ShardUnavailableError

CHAOS_MODES = ("drop", "delay", "close", "corrupt")


class ChaosClient(ShardClient):
    """Inject ``mode`` at the ``at``-th request (1-based), then every
    ``every`` requests after that (0 = fire once).  ``kinds`` restricts
    both counting and injection to the given request kinds, so a test can
    target e.g. exactly the second ``insert_batch`` of a workload."""

    def __init__(self, inner: ShardClient, mode: str, at: int = 1,
                 every: int = 0, delay_s: float = 0.05,
                 kinds: Optional[FrozenSet[str]] = None, seed: int = 0,
                 obs: Obs = NULL_OBS):
        if mode not in CHAOS_MODES:
            raise ValueError(f"unknown chaos mode {mode!r} "
                             f"(expected one of {CHAOS_MODES})")
        if at < 1:
            raise ValueError(f"at must be >= 1, got {at}")
        if mode in ("close", "corrupt") and not hasattr(inner, "_sock"):
            raise ValueError(
                f"chaos mode {mode!r} needs a socket-backed client, "
                f"got {type(inner).__name__}")
        # no super().__init__: the wire counters are read-through
        # properties here, not instance attributes
        self.shard_id = inner.shard_id
        self.obs = obs
        self.inner = inner
        self.mode = mode
        self.at = int(at)
        self.every = int(every)
        self.delay_s = float(delay_s)
        self.kinds = kinds
        self.seen = 0        # matching requests observed
        self.injected = 0    # faults actually fired
        self._rng = np.random.default_rng(seed)
        self._c_injected = obs.counter("chaos.injected")

    # wire counters mirror the wrapped client (the chaos layer itself
    # moves no bytes)
    @property
    def bytes_sent(self) -> int:  # type: ignore[override]
        return self.inner.bytes_sent

    @property
    def bytes_received(self) -> int:  # type: ignore[override]
        return self.inner.bytes_received

    @property
    def round_trips(self) -> int:  # type: ignore[override]
        return self.inner.round_trips

    # ------------------------------------------------------------------ #
    def _fires(self, req: m.Message) -> bool:
        if self.kinds is not None and req.kind not in self.kinds:
            return False
        self.seen += 1
        n = self.seen
        if n < self.at:
            return False
        if n == self.at or (self.every and (n - self.at) % self.every == 0):
            self.injected += 1
            self._c_injected.inc()
            return True
        return False

    def request(self, req: m.Message) -> m.Message:
        if not self._fires(req):
            return self.inner.request(req)
        if self.mode == "drop":
            raise ShardUnavailableError(
                self.shard_id,
                f"chaos drop at request {self.seen} ({req.kind})")
        if self.mode == "delay":
            time.sleep(self.delay_s)
            return self.inner.request(req)
        if self.mode == "close":
            sock = getattr(self.inner, "_sock", None)
            if sock is not None:
                sock.close()  # the transport sees a dead connection next
            return self.inner.request(req)
        return self._corrupt(req)

    def _corrupt(self, req: m.Message) -> m.Message:
        """Send a bit-flipped (but correctly framed) copy of the request
        on the wrapped client's socket and return the server's answer —
        an error frame, raised here exactly as any wire error would be.
        One frame out, one frame in: the connection stays aligned."""
        sock = self.inner._sock  # type: ignore[attr-defined]
        if sock is None:
            raise ShardUnavailableError(self.shard_id,
                                        "chaos corrupt: transport closed")
        payload = bytearray(encode(req))
        flips = self._rng.integers(0, len(payload), size=8)
        for pos in flips:
            payload[pos] ^= 0xFF
        write_frame(sock, bytes(payload))
        frame = read_frame(sock)
        if frame is None:
            raise ShardUnavailableError(
                self.shard_id, "worker closed the connection on a "
                               "corrupt frame (it should answer and live)")
        resp = decode(frame)
        if isinstance(resp, m.ErrorResp):
            raise _service.WIRE_ERRORS.get(resp.etype, RuntimeError)(resp.arg)
        return resp

    def close(self) -> None:
        self.inner.close()
