"""Typed wire messages of the shard protocol.

One message class per operation in the paper's AddPoint / DeletePoint /
GetCluster set (plus the structural queries the sharded hot path needs:
``component_of`` / ``core_anchor_of`` / ``drain_deltas``, and the
lifecycle ops: snapshot / restore / stats / shutdown).  A message is a
plain dataclass whose fields are either

  * fixed-dtype numpy arrays (declared in ``_dtypes`` and coerced at
    construction, so both ends of the wire agree bit-for-bit),
  * string-keyed dicts of arrays (declared in ``_array_dicts`` — used for
    snapshot state payloads), or
  * JSON-able scalars/dicts (everything else).

The split is what makes the npz framing codec (:mod:`repro.service.codec`)
generic: arrays travel as raw ``.npy`` members, everything else in one
JSON header.  ``None`` marks an optional field as absent.

Mutation responses piggyback two digests for the coordinator:

  * ``digest`` on :class:`InsertBatchResp` — the inserted points'
    bucket-key digest, one ``(t, w)`` row per point in request order
    (``w = d`` int64 grid codes for exact-key engines, ``w = 2`` int32
    mixed keys for the device-hash engines).  Feeding the coordinator's
    :class:`~repro.shard.bridge.BoundaryBridge` directory from this
    digest moves the full t-table hash off the coordinator: it routes on
    a table-0-only pass and the shards hash in parallel.
  * ``n_live`` on every mutation response — the shard's live-point count
    (the support-side digest the coordinator's stats/rebalance planning
    read without an extra round trip).
"""

from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Dict, Optional, Tuple, Type

import numpy as np

MESSAGE_TYPES: Dict[str, Type["Message"]] = {}

#: request kinds that change shard state.  A retrying transport must not
#: re-apply these blindly: it stamps them with a per-client monotonic
#: op-sequence number (``Message.op_seq``) and the service deduplicates —
#: a redelivered mutation returns the cached response instead of applying
#: twice.  ``drain_deltas`` is included because draining consumes the
#: change journal: a lost response must replay from the cache, not drain
#: a second (empty) time.
MUTATION_KINDS = frozenset(
    {"insert_batch", "delete_batch", "restore", "drain_deltas"})


def register_message(cls: Type["Message"]) -> Type["Message"]:
    """Class decorator: key ``cls`` by its ``kind`` for the codec."""
    if not cls.kind:
        raise ValueError(f"{cls.__name__} has no kind")
    if cls.kind in MESSAGE_TYPES:
        raise ValueError(f"duplicate message kind {cls.kind!r}")
    MESSAGE_TYPES[cls.kind] = cls
    return cls


@dataclasses.dataclass
class Message:
    kind: ClassVar[str] = ""
    #: observability sidecar, NOT dataclass fields: ``trace_ctx`` is the
    #: caller's span context (``{"t": trace_id, "s": span_id}``) and
    #: ``span_summary`` the server's finished-span exports riding back on
    #: a response.  They travel in the codec's JSON header under reserved
    #: ``__trace__``/``__spans__`` keys only when set, so an un-traced
    #: message encodes to bit-identical wire bytes.
    trace_ctx: ClassVar[Optional[Dict[str, int]]] = None
    span_summary: ClassVar[Optional[list]] = None
    #: exactly-once sidecar for retried mutations: ``(client_id, n)``
    #: where ``n`` is the sender's monotonic op-sequence number.  Rides
    #: the codec's JSON header under the reserved ``__seq__`` key only
    #: when set (same bit-identical-when-unused contract as the trace
    #: sidecar); the service's dedup table is keyed by it.
    op_seq: ClassVar[Optional[Tuple[str, int]]] = None
    #: field -> required numpy dtype (coerced in __post_init__)
    _dtypes: ClassVar[Dict[str, Any]] = {}
    #: field -> tuple of permitted fixed dtypes, for payloads whose width
    #: legitimately varies by engine family (e.g. the insert digest:
    #: int64 exact grid codes vs int32 device-hash mixed keys) — the
    #: array must already be one of them; never coerced, never object
    _poly_dtypes: ClassVar[Dict[str, Tuple[Any, ...]]] = {}
    #: fields holding {str: ndarray} payloads (snapshot state)
    _array_dicts: ClassVar[Tuple[str, ...]] = ()

    def __post_init__(self) -> None:
        for name, dtype in self._dtypes.items():
            v = getattr(self, name)
            if v is not None:
                object.__setattr__(
                    self, name, np.ascontiguousarray(v, dtype=dtype))
        for name, allowed in self._poly_dtypes.items():
            v = getattr(self, name)
            if v is not None:
                v = np.ascontiguousarray(v)
                if v.dtype not in tuple(np.dtype(a) for a in allowed):
                    raise TypeError(
                        f"{type(self).__name__}.{name} dtype {v.dtype} not "
                        f"in {tuple(np.dtype(a).name for a in allowed)}")
                object.__setattr__(self, name, v)


# ---------------------------------------------------------------------- #
# mutations
# ---------------------------------------------------------------------- #
@register_message
@dataclasses.dataclass
class InsertBatchReq(Message):
    kind = "insert_batch"
    _dtypes = {"X": np.float64, "ids": np.int64}
    X: np.ndarray            # (n, d) points
    ids: np.ndarray          # (n,) pre-claimed handles
    want_digest: bool = False  # piggyback the bucket-key digest


@register_message
@dataclasses.dataclass
class InsertBatchResp(Message):
    kind = "insert_batch_resp"
    _dtypes = {"ids": np.int64}
    # int64 = exact grid codes, int32 = device-hash mixed keys
    _poly_dtypes = {"digest": (np.int64, np.int32)}
    ids: np.ndarray                       # (n,) assigned handles
    digest: Optional[np.ndarray] = None   # (n, t, w) bucket-key digest
    n_live: int = 0


@register_message
@dataclasses.dataclass
class DeleteBatchReq(Message):
    kind = "delete_batch"
    _dtypes = {"ids": np.int64}
    ids: np.ndarray          # (n,) handles to delete


@register_message
@dataclasses.dataclass
class OkResp(Message):
    kind = "ok"
    n_live: int = 0


# ---------------------------------------------------------------------- #
# queries
# ---------------------------------------------------------------------- #
@register_message
@dataclasses.dataclass
class LabelsReq(Message):
    kind = "labels"
    _dtypes = {"ids": np.int64}
    ids: Optional[np.ndarray] = None  # None = all live points


@register_message
@dataclasses.dataclass
class LabelsResp(Message):
    kind = "labels_resp"
    _dtypes = {"ids": np.int64, "labels": np.int64}
    ids: np.ndarray
    labels: np.ndarray


@register_message
@dataclasses.dataclass
class ComponentOfReq(Message):
    kind = "component_of"
    idx: int = 0


@register_message
@dataclasses.dataclass
class ComponentOfBatchReq(Message):
    """Batched native find — one round trip resolves a whole quotient
    build's representatives on this shard."""

    kind = "component_of_batch"
    _dtypes = {"ids": np.int64}
    ids: Optional[np.ndarray] = None


@register_message
@dataclasses.dataclass
class ValuesResp(Message):
    kind = "values"
    values: Optional[list] = None  # encoded handles, request order


@register_message
@dataclasses.dataclass
class CoreAnchorOfReq(Message):
    kind = "core_anchor_of"
    idx: int = 0


@register_message
@dataclasses.dataclass
class ValueResp(Message):
    kind = "value"
    value: Any = None  # int handle, encoded tuple handle, or None


@register_message
@dataclasses.dataclass
class DrainDeltasReq(Message):
    kind = "drain_deltas"


@register_message
@dataclasses.dataclass
class DrainDeltasResp(Message):
    kind = "drain_deltas_resp"
    _dtypes = {"deltas": np.int64}
    # (n, 3) rows of (idx, old, new); -1 encodes None (handles are >= 0)
    deltas: Optional[np.ndarray] = None
    tracked: bool = False


@register_message
@dataclasses.dataclass
class IdsReq(Message):
    kind = "ids"


@register_message
@dataclasses.dataclass
class IdsResp(Message):
    kind = "ids_resp"
    _dtypes = {"ids": np.int64}
    ids: np.ndarray


@register_message
@dataclasses.dataclass
class StatsReq(Message):
    kind = "stats"
    want_obs: bool = False  # also pull the shard's Obs.drain() payload


@register_message
@dataclasses.dataclass
class StatsResp(Message):
    kind = "stats_resp"
    stats: Optional[Dict[str, int]] = None
    n_live: int = 0
    obs: Optional[Dict[str, Any]] = None  # Obs.drain() when requested


# ---------------------------------------------------------------------- #
# lifecycle
# ---------------------------------------------------------------------- #
@register_message
@dataclasses.dataclass
class HelloReq(Message):
    """Handshake: capability discovery + liveness check in one trip.

    On an authenticated listener (worker ``--token``) the hello must be
    the connection's first message and carry the matching ``token``.
    ``client_id`` identifies the caller's mutation-dedup lane: the
    response echoes the highest op-sequence number the server has applied
    for it, so a reconnecting client knows whether an in-flight mutation
    landed before the connection died."""

    kind = "hello"
    token: Optional[str] = None
    client_id: Optional[str] = None


@register_message
@dataclasses.dataclass
class HelloResp(Message):
    kind = "hello_resp"
    backend: str = ""
    native_component_queries: bool = False
    n_live: int = 0
    last_seq: int = -1  # highest applied op_seq for req.client_id


@register_message
@dataclasses.dataclass
class SnapshotReq(Message):
    kind = "snapshot"


@register_message
@dataclasses.dataclass
class SnapshotResp(Message):
    kind = "snapshot_resp"
    _array_dicts = ("state",)
    state: Optional[Dict[str, np.ndarray]] = None


@register_message
@dataclasses.dataclass
class RestoreReq(Message):
    kind = "restore"
    _array_dicts = ("state",)
    config: Optional[Dict[str, Any]] = None
    state: Optional[Dict[str, np.ndarray]] = None


@register_message
@dataclasses.dataclass
class CheckInvariantsReq(Message):
    kind = "check_invariants"


@register_message
@dataclasses.dataclass
class ShutdownReq(Message):
    kind = "shutdown"


@register_message
@dataclasses.dataclass
class ErrorResp(Message):
    """An exception crossing the wire; the client re-raises it by name."""

    kind = "error"
    etype: str = "RuntimeError"
    arg: Any = None  # first exception arg when JSON-able, else str(exc)


# component-handle wire encoding: the engines' native find returns either
# a point handle (int) or an Euler-tour node payload (a flat tuple of
# strs/ints, e.g. ("edge", u, v)).  JSON turns tuples into lists, so the
# client re-tuples on decode — both transports then return the exact same
# handle values (the oracle-equivalence contract).
def encode_handle(v: Any) -> Any:
    if v is None or isinstance(v, (int, np.integer)):
        return None if v is None else int(v)
    if isinstance(v, (tuple, list)):
        return [e if isinstance(e, str) else int(e) for e in v]
    raise TypeError(f"component handle {v!r} is not wire-encodable")


def decode_handle(v: Any) -> Any:
    return tuple(v) if isinstance(v, list) else v


# handle-encoding helpers for DrainDeltasResp (-1 = None; handles >= 0)
def encode_deltas(deltas) -> np.ndarray:
    enc = lambda v: -1 if v is None else int(v)  # noqa: E731
    return np.asarray([(i, enc(old), enc(new)) for i, old, new in deltas],
                      dtype=np.int64).reshape(-1, 3)


def decode_deltas(arr: np.ndarray) -> list:
    dec = lambda v: None if v == -1 else int(v)  # noqa: E731
    return [(int(r[0]), dec(r[1]), dec(r[2])) for r in arr]
