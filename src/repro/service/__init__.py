"""repro.service — the shard wire protocol, transport-agnostic.

The shard-facing API surface of the sharded backend, reified as typed
request/response messages with fixed-dtype numpy payloads over a
length-prefixed npz framing codec:

    from repro.service import (ClusterService, LocalTransport,
                               ProcessTransport, connect_shards)

  * :mod:`~repro.service.messages` — ``InsertBatchReq`` /
    ``DeleteBatchReq`` / ``LabelsReq`` / ``ComponentOfReq`` /
    ``SnapshotReq`` / ``DrainDeltasReq`` / … and their responses;
  * :mod:`~repro.service.codec` — message <-> npz frame;
  * :class:`~repro.service.service.ClusterService` — any registered
    ClusterIndex backend served behind the protocol;
  * :class:`~repro.service.transport.ShardClient` — the client ABC with
    three transports: ``LocalTransport`` (in-process, zero-copy),
    ``ProcessTransport`` (spawned per-shard server processes, GIL-free
    update fan-out) and ``TcpTransport`` (reconnectable stream socket
    with timeouts, bounded-backoff retries, token auth and exactly-once
    mutations via the op-sequence dedup header).
    ``ClusterConfig(transport="local"|"process"|"tcp")`` selects one for
    ``backend="sharded"``;
  * :class:`~repro.service.replica.ReplicatedClient` — a fault-tolerant
    lane of ``1 + R`` members per shard (``ClusterConfig.replicas``):
    deterministic update replay keeps replicas bit-identical, a dead
    primary is promoted away, dead members respawn + resync in the
    background;
  * :class:`~repro.service.chaos.ChaosClient` — fault injection
    (drop/delay/close/corrupt at the Nth request) around any client,
    plus the worker's ``--die-after N`` crash knob, so the recovery
    machinery is tested against real failures.
"""

from .chaos import CHAOS_MODES, ChaosClient  # noqa: F401
from .codec import decode, encode, read_frame, write_frame  # noqa: F401
from .messages import MESSAGE_TYPES, MUTATION_KINDS, Message  # noqa: F401
from .messages import (  # noqa: F401
    CheckInvariantsReq,
    ComponentOfBatchReq,
    ComponentOfReq,
    CoreAnchorOfReq,
    DeleteBatchReq,
    DrainDeltasReq,
    DrainDeltasResp,
    ErrorResp,
    HelloReq,
    HelloResp,
    IdsReq,
    IdsResp,
    InsertBatchReq,
    InsertBatchResp,
    LabelsReq,
    LabelsResp,
    OkResp,
    RestoreReq,
    ShutdownReq,
    SnapshotReq,
    SnapshotResp,
    StatsReq,
    StatsResp,
    ValueResp,
    ValuesResp,
)
from .replica import ReplicatedClient, connect_lanes  # noqa: F401
from .service import ClusterService, serve_connection  # noqa: F401
from .transport import (  # noqa: F401
    TRANSPORTS,
    LocalTransport,
    ProcessTransport,
    ShardClient,
    ShardUnavailableError,
    TcpTransport,
    connect_shards,
)
