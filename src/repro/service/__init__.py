"""repro.service — the shard wire protocol, transport-agnostic.

The shard-facing API surface of the sharded backend, reified as typed
request/response messages with fixed-dtype numpy payloads over a
length-prefixed npz framing codec:

    from repro.service import (ClusterService, LocalTransport,
                               ProcessTransport, connect_shards)

  * :mod:`~repro.service.messages` — ``InsertBatchReq`` /
    ``DeleteBatchReq`` / ``LabelsReq`` / ``ComponentOfReq`` /
    ``SnapshotReq`` / ``DrainDeltasReq`` / … and their responses;
  * :mod:`~repro.service.codec` — message <-> npz frame;
  * :class:`~repro.service.service.ClusterService` — any registered
    ClusterIndex backend served behind the protocol;
  * :class:`~repro.service.transport.ShardClient` — the client ABC with
    two transports: ``LocalTransport`` (in-process, zero-copy) and
    ``ProcessTransport`` (spawned per-shard server processes, GIL-free
    update fan-out).  ``ClusterConfig(transport="local"|"process")``
    selects one for ``backend="sharded"``; cross-host sharding is "write
    a TCP ``request()``", not a redesign.
"""

from .codec import decode, encode, read_frame, write_frame  # noqa: F401
from .messages import MESSAGE_TYPES, Message  # noqa: F401
from .messages import (  # noqa: F401
    CheckInvariantsReq,
    ComponentOfBatchReq,
    ComponentOfReq,
    CoreAnchorOfReq,
    DeleteBatchReq,
    DrainDeltasReq,
    DrainDeltasResp,
    ErrorResp,
    HelloReq,
    HelloResp,
    IdsReq,
    IdsResp,
    InsertBatchReq,
    InsertBatchResp,
    LabelsReq,
    LabelsResp,
    OkResp,
    RestoreReq,
    ShutdownReq,
    SnapshotReq,
    SnapshotResp,
    StatsReq,
    StatsResp,
    ValueResp,
    ValuesResp,
)
from .service import ClusterService, serve_connection  # noqa: F401
from .transport import (  # noqa: F401
    TRANSPORTS,
    LocalTransport,
    ProcessTransport,
    ShardClient,
    ShardUnavailableError,
    connect_shards,
)
