"""``ClusterService`` — any registered ClusterIndex behind the protocol.

``handle(req) -> resp`` is the whole server: a typed dispatch from the
message classes in :mod:`repro.service.messages` onto the wrapped index's
:class:`~repro.api.index.ClusterIndex` methods.  It raises on error — the
*connection* loop (:func:`serve_connection`) is what converts exceptions
to :class:`~repro.service.messages.ErrorResp` frames, so the in-process
transport sees native exceptions with zero translation.

The service also owns the shard-side half of the insert digest: when an
``InsertBatchReq`` asks for one, it runs the same seeded GridLSH pass the
inner engine keys its buckets with (exact int64 codes, or the float32
mixed keys for the device-hash engines) and piggybacks the result on the
response, so the coordinator can feed its boundary-bucket directory
without hashing the batch itself.
"""

from __future__ import annotations

import socket
from typing import Callable, Dict, Optional, Type

import numpy as np

from ..api.backends import MIXED_KEY_BACKENDS
from ..api.index import ClusterIndex
from ..core.hashing import GridLSH
from . import messages as m
from .codec import decode, encode, read_frame, write_frame

#: exception names the protocol maps back to native types client-side
WIRE_ERRORS: Dict[str, Type[BaseException]] = {
    "KeyError": KeyError,
    "ValueError": ValueError,
    "TypeError": TypeError,
    "NotImplementedError": NotImplementedError,
    "AssertionError": AssertionError,
    "PermissionError": PermissionError,
}


class ClusterService:
    """One index, served request by request (single-threaded: a shard's
    engine is only ever touched by its one connection, mirroring the
    one-worker-per-shard rule of the thread-pool fan-out)."""

    def __init__(self, index: ClusterIndex):
        self.index = index
        self.obs = index.obs  # server-side handle; NULL_OBS when cfg.obs off
        cfg = index.cfg
        self._mixed = cfg.backend in MIXED_KEY_BACKENDS
        self._lsh = GridLSH(cfg.d, cfg.eps, cfg.t, seed=cfg.seed)
        self._dispatch: Dict[type, Callable] = {
            m.HelloReq: self._hello,
            m.InsertBatchReq: self._insert_batch,
            m.DeleteBatchReq: self._delete_batch,
            m.LabelsReq: self._labels,
            m.ComponentOfReq: self._component_of,
            m.ComponentOfBatchReq: self._component_of_batch,
            m.CoreAnchorOfReq: self._core_anchor_of,
            m.DrainDeltasReq: self._drain_deltas,
            m.IdsReq: self._ids,
            m.StatsReq: self._stats,
            m.SnapshotReq: self._snapshot,
            m.RestoreReq: self._restore,
            m.CheckInvariantsReq: self._check_invariants,
            m.ShutdownReq: lambda req: m.OkResp(n_live=len(self.index)),
        }
        # mutation dedup: highest applied op_seq (and its response) per
        # client id.  A retrying transport redelivers a mutation with the
        # same sequence number after a reconnect; replaying the cached
        # response instead of re-dispatching makes delivery exactly-once.
        self._applied_seq: Dict[str, int] = {}
        self._applied_resp: Dict[str, m.Message] = {}

    # ------------------------------------------------------------------ #
    def handle(self, req: m.Message) -> m.Message:
        seq = req.op_seq
        if seq is not None and req.kind in m.MUTATION_KINDS:
            cid, n = str(seq[0]), int(seq[1])
            if n <= self._applied_seq.get(cid, -1):
                self.obs.counter("rpc.dedup_hits").inc()
                return self._applied_resp[cid]
            resp = self._handle(req)
            self._applied_seq[cid] = n
            self._applied_resp[cid] = resp
            return resp
        return self._handle(req)

    def _handle(self, req: m.Message) -> m.Message:
        try:
            fn = self._dispatch[type(req)]
        except KeyError:
            raise TypeError(
                f"unhandled request {type(req).__name__}") from None
        ctx = req.trace_ctx
        if ctx is None or not self.obs.enabled:
            return fn(req)
        # traced request: record a server-side span parented under the
        # caller's wire span, and piggyback every finished span (this one
        # plus any the engine recorded) on the response
        tracer = self.obs.tracer
        with tracer.adopt(ctx):
            with tracer.span("shard." + req.kind):
                resp = fn(req)
        resp.span_summary = tracer.drain_export()
        return resp

    def digest(self, X: np.ndarray) -> np.ndarray:
        """(n, d) -> (n, t, w) bucket-key digest in the wrapped engine's
        key family (bit-identical to the keys the engine buckets by).

        This re-runs the vectorised hash pass the engine already did
        internally; system-wide that is the same one-extra-pass the
        coordinator used to pay (now parallel across shards), and it is
        a tiny fraction of the pure-Python forest update the insert just
        performed.  Reassembling the engine's stored per-point key bytes
        back into a fixed-dtype array would cost a Python loop instead."""
        if self._mixed:
            return self._lsh.device_keys_batch(X)
        return self._lsh.codes_batch(X)

    # ------------------------------------------------------------------ #
    def _hello(self, req: m.HelloReq) -> m.HelloResp:
        last = (self._applied_seq.get(req.client_id, -1)
                if req.client_id else -1)
        return m.HelloResp(
            backend=self.index.cfg.backend,
            native_component_queries=bool(
                self.index.native_component_queries),
            n_live=len(self.index), last_seq=last)

    def _insert_batch(self, req: m.InsertBatchReq) -> m.InsertBatchResp:
        ids = self.index.insert_batch(req.X, ids=[int(i) for i in req.ids])
        digest = self.digest(req.X) if req.want_digest else None
        return m.InsertBatchResp(ids=np.asarray(ids, dtype=np.int64),
                                 digest=digest, n_live=len(self.index))

    def _delete_batch(self, req: m.DeleteBatchReq) -> m.OkResp:
        self.index.delete_batch([int(i) for i in req.ids])
        return m.OkResp(n_live=len(self.index))

    def _labels(self, req: m.LabelsReq) -> m.LabelsResp:
        lab = self.index.labels(
            None if req.ids is None else [int(i) for i in req.ids])
        ids = np.fromiter(lab.keys(), dtype=np.int64, count=len(lab))
        return m.LabelsResp(
            ids=ids,
            labels=np.fromiter(lab.values(), dtype=np.int64, count=len(lab)))

    def _component_of(self, req: m.ComponentOfReq) -> m.ValueResp:
        return m.ValueResp(
            value=m.encode_handle(self.index.component_of(req.idx)))

    # hot-path
    def _component_of_batch(self, req: m.ComponentOfBatchReq) -> m.ValuesResp:
        comp = self.index.component_of  # bound once: the hot dispatch
        return m.ValuesResp(
            values=[m.encode_handle(comp(int(i))) for i in req.ids])

    def _core_anchor_of(self, req: m.CoreAnchorOfReq) -> m.ValueResp:
        v = self.index.core_anchor_of(req.idx)
        return m.ValueResp(value=None if v is None else int(v))

    def _drain_deltas(self, req: m.DrainDeltasReq) -> m.DrainDeltasResp:
        deltas = self.index.drain_deltas()
        if deltas is None:
            return m.DrainDeltasResp(tracked=False)
        return m.DrainDeltasResp(deltas=m.encode_deltas(deltas), tracked=True)

    def _ids(self, req: m.IdsReq) -> m.IdsResp:
        return m.IdsResp(ids=np.asarray(self.index.ids(), dtype=np.int64))

    def _stats(self, req: m.StatsReq) -> m.StatsResp:
        obs = self.obs.drain() if req.want_obs else None
        return m.StatsResp(stats={k: int(v)
                                  for k, v in self.index.stats().items()},
                           n_live=len(self.index), obs=obs)

    def _snapshot(self, req: m.SnapshotReq) -> m.SnapshotResp:
        return m.SnapshotResp(state=self.index.snapshot()["state"])

    def _restore(self, req: m.RestoreReq) -> m.OkResp:
        self.index.restore({"config": dict(req.config),
                            "state": dict(req.state or {})})
        return m.OkResp(n_live=len(self.index))

    def _check_invariants(self, req: m.CheckInvariantsReq) -> m.OkResp:
        self.index.check_invariants()
        return m.OkResp(n_live=len(self.index))


def serve_connection(service: ClusterService, sock: socket.socket,
                     auth_token: Optional[str] = None) -> bool:
    """Frame loop: decode request, handle, encode response; exceptions —
    including an undecodable frame, e.g. an unknown message kind from a
    version-skewed peer — become ErrorResp frames (first arg when
    JSON-able, else ``str``), so a bad request never kills the shard.

    With ``auth_token`` set, the connection's first message must be a
    HelloReq carrying the matching token; anything else gets one
    ``PermissionError`` frame and the connection closes (a TCP listener
    keeps accepting — a failed login never kills the worker).

    Returns True when a ShutdownReq ended the loop (the server should
    exit), False on EOF (a reconnecting client may come back)."""
    authed = auth_token is None
    while True:
        payload = read_frame(sock)
        if payload is None:
            return False
        req = None
        try:
            req = decode(payload)
            if not authed:
                if (isinstance(req, m.HelloReq)
                        and req.token == auth_token):
                    authed = True
                else:
                    write_frame(sock, encode(m.ErrorResp(
                        etype="PermissionError",
                        arg="authentication required: send HelloReq with "
                            "the worker's token first")))
                    return False
            resp = service.handle(req)
        except BaseException as e:  # noqa: BLE001 — everything crosses the wire
            arg = e.args[0] if (e.args and isinstance(
                e.args[0], (str, int, float, bool))) else str(e)
            resp = m.ErrorResp(etype=type(e).__name__, arg=arg)
        write_frame(sock, encode(resp))
        if isinstance(req, m.ShutdownReq):
            return True
