"""Length-prefixed npz framing for the shard wire protocol.

A message serialises to one npz archive (uncompressed zip of ``.npy``
members — numpy's own format, so dtypes/shapes round-trip exactly):

  * every array field -> member ``a:<field>``;
  * every array-dict field -> members ``d:<field>/<key>`` (snapshot state
    dicts keep their keys, including ``/``-nested ones);
  * everything else -> one JSON header member ``__meta__`` (uint8 bytes)
    holding ``{"kind": ..., <scalar fields>}``; ``None``/absent fields are
    simply omitted.

On the wire each message is one frame: an 8-byte big-endian length prefix
followed by the npz payload.  The framing is transport-agnostic — the
in-process transport skips it entirely, the process transport runs it over
a socket pair, and a future TCP transport reuses it unchanged.
"""

from __future__ import annotations

import dataclasses
import io
import json
import socket
import struct
from typing import Dict, Optional

import numpy as np

from .messages import MESSAGE_TYPES, Message

_LEN = struct.Struct(">Q")
MAX_FRAME = 1 << 40  # sanity bound: a corrupt length prefix fails fast


# ---------------------------------------------------------------------- #
# message <-> npz payload
# ---------------------------------------------------------------------- #
def _wire_array(owner: str, name: str, arr: np.ndarray) -> np.ndarray:
    """Refuse object/void arrays at encode time: decode runs with
    ``allow_pickle=False``, so letting one through here would serialise
    fine locally and explode on the *peer* — fail on the sender instead."""
    if arr.dtype.kind in ("O", "V"):
        raise TypeError(
            f"{owner}.{name} has non-fixed dtype {arr.dtype!r}; "
            "object arrays cannot cross the wire unpickled")
    return arr


def encode(msg: Message) -> bytes:
    meta: Dict[str, object] = {"kind": msg.kind}
    arrays: Dict[str, np.ndarray] = {}
    owner = type(msg).__name__
    for f in dataclasses.fields(msg):
        v = getattr(msg, f.name)
        if v is None:
            continue
        if f.name in msg._array_dicts:
            for key, arr in v.items():
                arrays[f"d:{f.name}/{key}"] = _wire_array(
                    owner, f"{f.name}[{key!r}]", np.asarray(arr))
        elif isinstance(v, np.ndarray):
            arrays[f"a:{f.name}"] = _wire_array(owner, f.name, v)
        else:
            meta[f.name] = v
    # observability sidecar: reserved header keys, present only when the
    # message was traced — absent, the bytes match the un-instrumented tree
    if msg.trace_ctx is not None:
        meta["__trace__"] = msg.trace_ctx
    if msg.span_summary:
        meta["__spans__"] = msg.span_summary
    if msg.op_seq is not None:
        meta["__seq__"] = list(msg.op_seq)
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def decode(payload: bytes) -> Message:
    with np.load(io.BytesIO(payload), allow_pickle=False) as npz:
        meta = json.loads(bytes(npz["__meta__"]).decode("utf-8"))
        kind = meta.pop("kind")
        trace_ctx = meta.pop("__trace__", None)
        span_summary = meta.pop("__spans__", None)
        op_seq = meta.pop("__seq__", None)
        try:
            cls = MESSAGE_TYPES[kind]
        except KeyError:
            raise ValueError(f"unknown message kind {kind!r}") from None
        fields = {f.name for f in dataclasses.fields(cls)}
        kwargs: Dict[str, object] = {
            k: v for k, v in meta.items() if k in fields}
        dicts: Dict[str, Dict[str, np.ndarray]] = {}
        for name in npz.files:
            if name == "__meta__":
                continue
            tag, _, rest = name.partition(":")
            if tag == "a":
                kwargs[rest] = npz[name]
            elif tag == "d":
                fname, _, key = rest.partition("/")
                dicts.setdefault(fname, {})[key] = npz[name]
        kwargs.update(dicts)
        msg = cls(**kwargs)
        if trace_ctx is not None:
            msg.trace_ctx = trace_ctx
        if span_summary is not None:
            msg.span_summary = span_summary
        if op_seq is not None:
            msg.op_seq = (str(op_seq[0]), int(op_seq[1]))
        return msg


# ---------------------------------------------------------------------- #
# frames over a stream socket
# ---------------------------------------------------------------------- #
def write_frame(sock: socket.socket, payload: bytes) -> int:
    frame = _LEN.pack(len(payload)) + payload
    sock.sendall(frame)
    return len(frame)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise EOFError("peer closed the connection mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def read_frame(sock: socket.socket) -> Optional[bytes]:
    """Next frame payload, or None on clean EOF at a frame boundary."""
    head = b""
    while len(head) < _LEN.size:
        chunk = sock.recv(_LEN.size - len(head))
        if not chunk:
            if head:
                raise EOFError("peer closed the connection mid-frame")
            return None
        head += chunk
    (n,) = _LEN.unpack(head)
    if n > MAX_FRAME:
        raise ValueError(f"frame length {n} exceeds MAX_FRAME")
    return _recv_exact(sock, n)
