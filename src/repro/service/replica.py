"""Replicated shard lanes: promote-on-failure fault tolerance.

A :class:`ReplicatedClient` is a :class:`~repro.service.transport.ShardClient`
made of ``1 + R`` member clients — one primary plus ``R`` replicas
(``ClusterConfig.replicas``), each a full worker holding the same shard
state.  Replicas are fed by deterministic update replay: every mutation
the lane applies to its primary is teed, in order, to every replica (the
engines are deterministic given the op sequence, so members stay
bit-identical — :meth:`ReplicatedClient.verify_replicas` checks the
snapshots byte for byte).  Queries go to the primary only.

Failure handling is the coordinator-side half of the fleet story:

  * a member that raises
    :class:`~repro.service.transport.ShardUnavailableError` (its transport
    already burned its retry budget, so this is a *dead* worker, not a
    blip) is evicted from the lane's
    :class:`~repro.runtime.heartbeat.HeartbeatRegistry` slot;
  * a dead **primary** triggers promotion: the first live replica —
    in lockstep by construction — becomes primary and the in-flight
    request is re-issued against it (``failover.promotions`` counts
    these, under a ``failover.promote`` span);
  * a dead **replica** just leaves the lane (``failover.replica_drops``);
  * either way the lane heals itself in the background: a fresh worker is
    spawned, restored from a snapshot of the surviving primary, fed the
    mutations that arrived while it was rebuilding (the lane journals
    them), and atomically joined back into the lane
    (``failover.resyncs``).  The snapshot is taken synchronously in the
    *calling* thread — member transports are single-socket and not
    thread-safe, so the background thread only ever touches the one
    client it is building.

Every member occupies a fixed heartbeat slot (``0..R``); successful
requests beat the slot, :meth:`ReplicatedClient.check_health` probes idle
members and evicts/promotes anyone who missed the registry deadline —
the same deadline discipline a multi-host deployment would drive from
real heartbeat traffic.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from ..api.config import ClusterConfig
from ..obs import NULL_OBS, Obs
from ..runtime.heartbeat import HeartbeatRegistry
from . import messages as m
from .transport import (TRANSPORTS, ShardClient, ShardUnavailableError)


@dataclasses.dataclass
class _Member:
    client: ShardClient
    slot: int  # fixed heartbeat-registry slot, 0..R


@dataclasses.dataclass
class _Repair:
    """A respawn+resync in flight: the snapshot it restores from and the
    journal of mutations that arrived after that snapshot was taken."""

    slot: int
    snapshot: Dict[str, np.ndarray]
    journal: List[m.Message] = dataclasses.field(default_factory=list)
    cancelled: bool = False
    thread: Optional[threading.Thread] = None


class ReplicatedClient(ShardClient):
    """A lane of member ShardClients behind the plain ShardClient surface.

    ``factory()`` must return a fresh, empty member client (it is called
    ``1 + replicas`` times up front and once per background respawn).
    The lane serialises itself with one lock: the coordinator's fan-out
    touches each shard with at most one thread at a time, so the only
    contention is with the lane's own repair thread, which takes the lock
    only to drain its journal and to join.
    """

    def __init__(self, factory: Callable[[], ShardClient],
                 inner_cfg: ClusterConfig, shard_id: int = 0,
                 replicas: int = 1, obs: Obs = NULL_OBS,
                 heartbeat_timeout_s: float = 60.0,
                 clock: Optional[Callable[[], float]] = None,
                 respawn: bool = True):
        # no super().__init__: the wire counters are properties here
        # (summed over members), not instance attributes
        self.shard_id = shard_id
        self.obs = obs
        self._factory = factory
        self._inner_cfg = inner_cfg
        self._size = 1 + int(replicas)
        self._respawn = respawn
        self._lock = threading.RLock()
        self._closed = False
        self._beats = HeartbeatRegistry(self._size,
                                        timeout_s=heartbeat_timeout_s,
                                        clock=clock)
        self._repairs: List[_Repair] = []
        # bound once so the fleet counters exist (at zero) in every
        # instrumented snapshot, promoted or not
        self._c_promotions = obs.counter("failover.promotions")
        self._c_drops = obs.counter("failover.replica_drops")
        self._c_resyncs = obs.counter("failover.resyncs")
        self._c_respawn_failures = obs.counter("failover.respawn_failures")
        members: List[_Member] = []
        try:
            for slot in range(self._size):
                members.append(_Member(factory(), slot))
        except Exception:
            for mem in members:
                mem.client.close()
            raise
        self._members = members

    # ------------------------------------------------------------------ #
    # wire counters: the lane's cost is the sum of its members'
    # ------------------------------------------------------------------ #
    @property
    def bytes_sent(self) -> int:  # type: ignore[override]
        with self._lock:
            return sum(mem.client.bytes_sent for mem in self._members)

    @property
    def bytes_received(self) -> int:  # type: ignore[override]
        with self._lock:
            return sum(mem.client.bytes_received for mem in self._members)

    @property
    def round_trips(self) -> int:  # type: ignore[override]
        with self._lock:
            return sum(mem.client.round_trips for mem in self._members)

    @property
    def n_members(self) -> int:
        with self._lock:
            return len(self._members)

    @property
    def n_repairs(self) -> int:
        with self._lock:
            return len(self._repairs)

    # ------------------------------------------------------------------ #
    # failure handling (all called with the lane lock held)
    # ------------------------------------------------------------------ #
    def _fail_member(self, mem: _Member) -> None:
        """Evict a dead member; promotion happens implicitly (the lane's
        primary is always ``members[0]``).  Raises when the lane is out
        of members — the caller's op cannot complete, and the coordinator
        decides what that means."""
        promoted = mem is self._members[0]
        self._members.remove(mem)
        self._beats.evict(mem.slot)
        try:
            mem.client.close()
        except Exception:  # a dead worker's close is best-effort
            pass
        if promoted:
            self._c_promotions.inc()
        else:
            self._c_drops.inc()
        if not self._members:
            raise ShardUnavailableError(
                self.shard_id,
                f"no live members left in the lane "
                f"(size {self._size}, all evicted)")
        self._schedule_repair()

    def _schedule_repair(self) -> None:
        """Spawn+resync a replacement member in the background.  The
        snapshot comes off the surviving primary *now*, synchronously —
        the caller thread owns the primary's socket — and the journal
        collects every mutation from here to the join."""
        if not self._respawn or self._closed:
            return
        if len(self._members) + len(self._repairs) >= self._size:
            return
        taken = ({mem.slot for mem in self._members}
                 | {rep.slot for rep in self._repairs})
        slot = next(s for s in range(self._size) if s not in taken)
        snapshot = self._members[0].client.snapshot_state()
        rep = _Repair(slot=slot, snapshot=snapshot)
        self._repairs.append(rep)
        rep.thread = threading.Thread(
            target=self._repair_worker, args=(rep,),
            name=f"lane{self.shard_id}-repair", daemon=True)
        rep.thread.start()

    def _repair_worker(self, rep: _Repair) -> None:
        """Background half of the resync: build a fresh member, restore
        the snapshot, replay the journal until it runs dry, then join
        atomically.  Only this thread touches the new member's client
        until the join publishes it."""
        client: Optional[ShardClient] = None
        try:
            client = self._factory()
            client.restore(self._inner_cfg.to_dict(), rep.snapshot)
            # reset the change-feed baseline: deltas produced *before*
            # the snapshot are already baked into the restored state
            client.drain_deltas()
            while True:
                with self._lock:
                    if rep.cancelled:
                        break
                    if not rep.journal:
                        self._repairs.remove(rep)
                        self._members.append(_Member(client, rep.slot))
                        self._beats.rejoin(rep.slot)
                        self._c_resyncs.inc()
                        return
                    batch, rep.journal = rep.journal, []
                for msg in batch:  # replay outside the lock
                    client.request(msg)
        except Exception:
            self._c_respawn_failures.inc()
            with self._lock:
                if rep in self._repairs:
                    self._repairs.remove(rep)
        if client is not None:
            client.close()

    @staticmethod
    def _tee_copy(req: m.Message) -> m.Message:
        """Fresh message for a tee/journal delivery: each member's
        transport stamps its *own* op-sequence header, and replicas never
        recompute the insert digest the primary already returned."""
        if isinstance(req, m.InsertBatchReq):
            return dataclasses.replace(req, want_digest=False)
        return dataclasses.replace(req)

    # ------------------------------------------------------------------ #
    # the ShardClient surface
    # ------------------------------------------------------------------ #
    def request(self, req: m.Message) -> m.Message:
        with self._lock:
            if self._closed:
                raise ShardUnavailableError(self.shard_id, "lane closed")
            if req.kind in m.MUTATION_KINDS:
                return self._mutate(req)
            return self._apply_primary(req)

    def _apply_primary(self, req: m.Message) -> m.Message:
        """Primary request with promote-on-failure: a dead primary is
        evicted and the op re-issued against the promoted replica."""
        while True:
            mem = self._members[0]
            try:
                resp = mem.client.request(req)
            except ShardUnavailableError:
                with self.obs.tracer.span("failover.promote",
                                          shard=self.shard_id,
                                          slot=mem.slot):
                    self._fail_member(mem)  # raises when lane exhausted
                continue
            self._beats.beat(mem.slot)
            return resp

    def _mutate(self, req: m.Message) -> m.Message:
        resp = self._apply_primary(req)
        # journal to exactly the repairs whose snapshot predates this
        # mutation: everything in flight now — pre-existing repairs and
        # ones scheduled by a promotion *during* the primary apply (their
        # snapshot was taken before the re-issue landed).  A repair
        # scheduled by a tee failure below snapshots a primary that
        # already holds this mutation, so journaling it there would
        # double-apply.
        journal_to = list(self._repairs)
        for mem in list(self._members[1:]):
            try:
                mem.client.request(self._tee_copy(req))
            except ShardUnavailableError:
                self._fail_member(mem)
            else:
                self._beats.beat(mem.slot)
        for rep in journal_to:
            if rep in self._repairs:
                rep.journal.append(self._tee_copy(req))
        return resp

    def check_invariants(self) -> None:
        """Primary invariants + the replication oracle: every replica's
        snapshot must be byte-identical to the primary's."""
        self.request(m.CheckInvariantsReq())
        self.verify_replicas()

    def verify_replicas(self) -> None:
        """Assert primary ≡ replicas, array by array (the transport
        oracle of the replication scheme: replay is deterministic, so
        anything short of bit-identical is a divergence bug)."""
        with self._lock:
            if len(self._members) <= 1:
                return
            ref = self._members[0].client.snapshot_state()
            for mem in self._members[1:]:
                got = mem.client.snapshot_state()
                assert set(got) == set(ref), (
                    f"lane {self.shard_id}: replica slot {mem.slot} state "
                    f"keys {sorted(set(got) ^ set(ref))} differ")
                for key, arr in ref.items():
                    assert np.array_equal(got[key], arr), (
                        f"lane {self.shard_id}: replica slot {mem.slot} "
                        f"diverges from primary at state[{key!r}]")

    def check_health(self, probe: bool = True) -> None:
        """Deadline-based failure detection, callable from a serving
        loop's idle path: probe members (a HelloReq beats the slot), then
        evict anyone whose heartbeat slot missed the registry deadline.
        A dead primary is promoted exactly as on a failed request."""
        with self._lock:
            if self._closed:
                return
            if probe:
                for mem in list(self._members):
                    try:
                        mem.client.request(m.HelloReq())
                    except ShardUnavailableError:
                        with self.obs.tracer.span("failover.promote",
                                                  shard=self.shard_id,
                                                  slot=mem.slot):
                            self._fail_member(mem)
                    else:
                        self._beats.beat(mem.slot)
            overdue = set(self._beats.failed())
            for mem in list(self._members):
                if mem.slot in overdue:
                    with self.obs.tracer.span("failover.promote",
                                              shard=self.shard_id,
                                              slot=mem.slot):
                        self._fail_member(mem)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for rep in self._repairs:
                rep.cancelled = True
            threads = [rep.thread for rep in self._repairs if rep.thread]
            members, self._members = self._members, []
        for t in threads:
            t.join(timeout=10.0)
        for mem in members:
            mem.client.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass


def connect_lanes(inner_cfg: ClusterConfig, n_shards: int, transport: str,
                  replicas: int, obs: Obs = NULL_OBS,
                  heartbeat_timeout_s: float = 60.0,
                  respawn: bool = True) -> List[ShardClient]:
    """One replicated lane per shard — the ``cfg.replicas > 0`` analogue
    of :func:`~repro.service.transport.connect_shards`."""
    try:
        member_cls = TRANSPORTS[transport]
    except KeyError:
        raise ValueError(
            f"unknown transport {transport!r} "
            f"(expected one of {', '.join(sorted(TRANSPORTS))})") from None
    lanes: List[ShardClient] = []
    try:
        for s in range(n_shards):
            factory = (lambda s=s: member_cls(inner_cfg, shard_id=s,
                                              obs=obs))
            lanes.append(ReplicatedClient(
                factory, inner_cfg, shard_id=s, replicas=replicas, obs=obs,
                heartbeat_timeout_s=heartbeat_timeout_s, respawn=respawn))
    except Exception:
        for lane in lanes:
            lane.close()
        raise
    return lanes
