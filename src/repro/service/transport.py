"""Shard transports: how the coordinator reaches a shard's ClusterService.

``ShardClient`` is the one surface :class:`~repro.shard.index.ShardedIndex`
talks to — typed convenience methods built over a single ``request(req) ->
resp`` primitive, plus wire counters (``bytes_sent`` / ``bytes_received``
/ ``round_trips``) so benchmarks can report protocol overhead.

Two transports ship:

  * :class:`LocalTransport` — the index lives in-process; ``request`` is
    a direct ``ClusterService.handle`` call (no codec, no copy) and the
    per-point hot queries (``component_of`` / ``core_anchor_of``) are
    bound straight to the engine, preserving the pre-protocol behavior
    and performance exactly.
  * :class:`ProcessTransport` — the index lives in a spawned worker
    process (``python -m repro.service.worker``) reached over a unix
    socket pair; every request is one npz frame each way.  S shards means
    S independent interpreters, so the pure-Python forest updates run
    truly in parallel (the coordinator's fan-out threads just block on
    sockets, releasing the GIL) — the ~S× update speedup the in-process
    thread pool can never reach.

A worker that dies (crash, OOM, kill) surfaces as
:class:`ShardUnavailableError` on the next request — never a hang: a dead
peer closes the socket, which reads as EOF at the frame layer.

Cross-host sharding is a third transport away: implement ``request`` over
TCP and nothing above this module changes.
"""

from __future__ import annotations

import abc
import contextlib
import json
import os
import socket
import subprocess
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..api.config import ClusterConfig
from ..api.registry import build_index
from ..obs import NULL_OBS, Obs
from . import messages as m
from .codec import encode, decode, read_frame, write_frame
# module (not name) import: this module is reached from repro.api's
# registration of the sharded backend, which can run while .service is
# still initialising — resolve its names at call time, not import time
from . import service as _service


class ShardUnavailableError(RuntimeError):
    """A shard's server process is gone (exited, crashed, or unreachable)."""

    def __init__(self, shard: int, detail: str):
        super().__init__(f"shard {shard} unavailable: {detail}")
        self.shard = shard


class ShardClient(abc.ABC):
    """Typed client over one shard's ClusterService."""

    def __init__(self, shard_id: int = 0, obs: Obs = NULL_OBS):
        self.shard_id = shard_id
        #: the *coordinator's* Obs handle — wire spans and per-shard RPC
        #: metrics are client-side observations (the shard records its own
        #: server-side spans with its index's handle)
        self.obs = obs
        self.bytes_sent = 0
        self.bytes_received = 0
        self.round_trips = 0

    # ------------------------------------------------------------------ #
    @abc.abstractmethod
    def request(self, req: m.Message) -> m.Message:
        """One protocol round trip; raises the shard's exception natively."""

    def close(self) -> None:
        """Tear down the connection/worker; idempotent."""

    # ------------------------------------------------------------------ #
    # typed operations (the only shard surface ShardedIndex uses)
    # ------------------------------------------------------------------ #
    def hello(self) -> m.HelloResp:
        return self.request(m.HelloReq())

    def insert_batch(self, X: np.ndarray, ids: Sequence[int],
                     want_digest: bool = False
                     ) -> Tuple[List[int], Optional[np.ndarray]]:
        r = self.request(m.InsertBatchReq(X=X, ids=ids,
                                          want_digest=want_digest))
        return [int(i) for i in r.ids], r.digest

    def delete_batch(self, ids: Sequence[int]) -> None:
        self.request(m.DeleteBatchReq(ids=ids))

    def labels(self, ids=None) -> Dict[int, int]:
        r = self.request(m.LabelsReq(ids=None if ids is None else list(ids)))
        return {int(i): int(l) for i, l in zip(r.ids, r.labels)}

    def component_of(self, idx: int):
        """The shard's native component handle (opaque: an int or an
        Euler-tour node payload tuple, identical across transports)."""
        return m.decode_handle(self.request(m.ComponentOfReq(idx=int(idx))).value)

    def component_of_batch(self, ids: Sequence[int]) -> list:
        """Native component handles of ``ids``, one round trip."""
        r = self.request(m.ComponentOfBatchReq(ids=list(ids)))
        return [m.decode_handle(v) for v in r.values or []]

    def core_anchor_of(self, idx: int) -> Optional[int]:
        v = self.request(m.CoreAnchorOfReq(idx=int(idx))).value
        return None if v is None else int(v)

    def drain_deltas(self):
        r = self.request(m.DrainDeltasReq())
        if not r.tracked:
            return None
        return [] if r.deltas is None else m.decode_deltas(r.deltas)

    def ids(self) -> List[int]:
        return [int(i) for i in self.request(m.IdsReq()).ids]

    def stats(self) -> Tuple[Dict[str, int], int]:
        r = self.request(m.StatsReq())
        return dict(r.stats or {}), int(r.n_live)

    def pull_obs(self) -> Optional[dict]:
        """Drain the shard's server-side Obs payload (metrics snapshot +
        finished spans), or None when the shard is un-instrumented."""
        return self.request(m.StatsReq(want_obs=True)).obs

    def snapshot_state(self) -> Dict[str, np.ndarray]:
        return dict(self.request(m.SnapshotReq()).state or {})

    def restore(self, config: dict, state: Dict[str, np.ndarray]) -> None:
        self.request(m.RestoreReq(config=config, state=state))

    def check_invariants(self) -> None:
        self.request(m.CheckInvariantsReq())


class LocalTransport(ShardClient):
    """In-process shard: zero-copy dispatch straight into the service."""

    def __init__(self, cfg: ClusterConfig, shard_id: int = 0,
                 obs: Obs = NULL_OBS):
        super().__init__(shard_id, obs=obs)
        self.index = build_index(cfg)
        # label the in-process shard's own handle so its spans/metrics
        # land in a per-shard lane, matching the process transport
        self.index.obs.set_proc(f"shard{shard_id}")
        self.service = _service.ClusterService(self.index)
        # hot-path bindings: the sharded quotient build calls these
        # thousands of times per epoch — go straight to the engine, as the
        # pre-protocol code did (message objects would be pure overhead)
        self.component_of = self.index.component_of
        self.core_anchor_of = self.index.core_anchor_of

    def component_of_batch(self, ids):  # hot-path
        comp = self.index.component_of
        return [comp(int(i)) for i in ids]

    def request(self, req: m.Message) -> m.Message:
        self.round_trips += 1
        if self.obs.enabled:
            ctx = self.obs.tracer.context()
            if ctx is not None:
                req.trace_ctx = ctx
                resp = self.service.handle(req)
                if resp.span_summary:
                    self.obs.tracer.ingest(resp.span_summary)
                    resp.span_summary = None
                return resp
        return self.service.handle(req)

    @contextlib.contextmanager
    def _traced(self, op):
        """Shard-lane span for the zero-copy bulk ops: nothing crosses a
        wire here, but a traced run still renders the same
        coordinator -> shard tree as the process transport."""
        ctx = self.obs.tracer.context() if self.obs.enabled else None
        if ctx is None:
            yield
            return
        tr = self.index.obs.tracer
        with tr.adopt(ctx):
            with tr.span("shard." + op):
                yield
        self.obs.tracer.ingest(tr.drain_export())

    # bulk ops skip the message layer too: same arrays in, same dicts out
    def insert_batch(self, X, ids, want_digest=False):
        with self._traced("insert_batch"):
            out = self.index.insert_batch(X, ids=list(ids))
            return out, (self.service.digest(np.asarray(X, dtype=np.float64))
                         if want_digest else None)

    def delete_batch(self, ids):
        with self._traced("delete_batch"):
            self.index.delete_batch(list(ids))

    def labels(self, ids=None):
        with self._traced("labels"):
            return self.index.labels(ids)

    def drain_deltas(self):
        return self.index.drain_deltas()

    def ids(self):
        return self.index.ids()

    def stats(self):
        return self.index.stats(), len(self.index)

    def snapshot_state(self):
        return self.index.snapshot()["state"]

    def restore(self, config, state):
        self.index.restore({"config": dict(config), "state": dict(state)})

    def check_invariants(self):
        self.index.check_invariants()


class ProcessTransport(ShardClient):
    """Out-of-process shard: one spawned worker, one unix socket pair."""

    def __init__(self, cfg: ClusterConfig, shard_id: int = 0,
                 timeout: Optional[float] = None, obs: Obs = NULL_OBS):
        super().__init__(shard_id, obs=obs)
        self._cfg = cfg
        parent, child = socket.socketpair()
        try:
            env = dict(os.environ)
            # the worker must resolve `repro` exactly as this process does
            # (__path__, not __file__: repro is a namespace package)
            import repro
            pkg_root = os.path.dirname(
                os.path.abspath(list(repro.__path__)[0]))
            env["PYTHONPATH"] = pkg_root + (
                os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
            self._proc = subprocess.Popen(
                [sys.executable, "-m", "repro.service.worker",
                 "--fd", str(child.fileno()),
                 "--config", json.dumps(cfg.to_dict()),
                 "--proc", f"shard{shard_id}"],
                pass_fds=(child.fileno(),), env=env)
        finally:
            child.close()
        if timeout is not None:
            parent.settimeout(timeout)
        self._sock: Optional[socket.socket] = parent

    # ------------------------------------------------------------------ #
    def _gone(self, detail: str) -> ShardUnavailableError:
        code = self._proc.poll()
        if code is not None:
            detail = f"worker exited with code {code} ({detail})"
        return ShardUnavailableError(self.shard_id, detail)

    def request(self, req: m.Message) -> m.Message:  # hot-path
        if not self.obs.enabled:
            return self._roundtrip(req)
        # traced round trip: a client-side wire span whose context rides
        # the request header; the worker's spans come back piggybacked on
        # the response and fold into this process's buffer
        tracer = self.obs.tracer
        with tracer.span(f"wire.shard{self.shard_id}", op=req.kind) as sp:
            req.trace_ctx = sp.wire_ctx()
            resp = self._roundtrip(req)
        if resp.span_summary:
            tracer.ingest(resp.span_summary)
            resp.span_summary = None
        return resp

    def _roundtrip(self, req: m.Message) -> m.Message:  # hot-path
        if self._sock is None:
            raise ShardUnavailableError(self.shard_id, "transport closed")
        try:
            self.bytes_sent += write_frame(self._sock, encode(req))
            payload = read_frame(self._sock)
        except (OSError, EOFError) as e:
            raise self._gone(str(e) or type(e).__name__) from e
        if payload is None:
            raise self._gone("connection closed by peer")
        self.bytes_received += len(payload) + 8
        self.round_trips += 1
        resp = decode(payload)
        if isinstance(resp, m.ErrorResp):
            raise _service.WIRE_ERRORS.get(resp.etype, RuntimeError)(resp.arg)
        return resp

    def close(self) -> None:
        sock, self._sock = self._sock, None
        if sock is None:
            return
        try:
            write_frame(sock, encode(m.ShutdownReq()))
            read_frame(sock)
        except (OSError, EOFError):
            pass
        finally:
            sock.close()
        try:
            self._proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            self._proc.kill()
            self._proc.wait()

    def __del__(self):  # backstop: never leak worker processes
        try:
            self.close()
        except Exception:
            pass


TRANSPORTS = {"local": LocalTransport, "process": ProcessTransport}


def connect_shards(inner_cfg: ClusterConfig, n_shards: int,
                   transport: str, obs: Obs = NULL_OBS) -> List[ShardClient]:
    """Build/spawn one ShardClient per shard for ``transport``; ``obs``
    is the coordinator's handle (client-side wire spans/metrics)."""
    try:
        factory = TRANSPORTS[transport]
    except KeyError:
        raise ValueError(
            f"unknown transport {transport!r} "
            f"(expected one of {', '.join(sorted(TRANSPORTS))})") from None
    clients: List[ShardClient] = []
    try:
        for s in range(n_shards):
            clients.append(factory(inner_cfg, shard_id=s, obs=obs))
    except Exception:
        for c in clients:
            c.close()
        raise
    return clients
