"""Shard transports: how the coordinator reaches a shard's ClusterService.

``ShardClient`` is the one surface :class:`~repro.shard.index.ShardedIndex`
talks to — typed convenience methods built over a single ``request(req) ->
resp`` primitive, plus wire counters (``bytes_sent`` / ``bytes_received``
/ ``round_trips``) so benchmarks can report protocol overhead.

Three transports ship:

  * :class:`LocalTransport` — the index lives in-process; ``request`` is
    a direct ``ClusterService.handle`` call (no codec, no copy) and the
    per-point hot queries (``component_of`` / ``core_anchor_of``) are
    bound straight to the engine, preserving the pre-protocol behavior
    and performance exactly.
  * :class:`ProcessTransport` — the index lives in a spawned worker
    process (``python -m repro.service.worker``) reached over a unix
    socket pair; every request is one npz frame each way.  S shards means
    S independent interpreters, so the pure-Python forest updates run
    truly in parallel (the coordinator's fan-out threads just block on
    sockets, releasing the GIL) — the ~S× update speedup the in-process
    thread pool can never reach.
  * :class:`TcpTransport` — the same framed protocol over a stream
    socket, built for fleets where connections fail independently of
    workers: connect/request timeouts (``ClusterConfig.rpc_timeout_s``),
    bounded exponential-backoff retries with transparent reconnection,
    token auth on the hello handshake, and exactly-once mutations via the
    per-client op-sequence dedup header (see
    :data:`~repro.service.messages.MUTATION_KINDS`).  By default it
    spawns a local TCP worker; pass ``addr=(host, port)`` to reach a
    worker on another host.

A worker that dies (crash, OOM, kill) surfaces as
:class:`ShardUnavailableError` on the next request — never a hang: a dead
peer closes the socket (EOF at the frame layer), a wedged one trips the
per-op deadline.  ``ShardUnavailableError`` carries the retry/timeout
detail in its message so callers and tests can assert on what the
transport actually did before giving up.
"""

from __future__ import annotations

import abc
import contextlib
import json
import os
import secrets
import socket
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..api.config import ClusterConfig
from ..api.registry import build_index
from ..obs import NULL_OBS, Obs
from . import messages as m
from .codec import encode, decode, read_frame, write_frame
# module (not name) import: this module is reached from repro.api's
# registration of the sharded backend, which can run while .service is
# still initialising — resolve its names at call time, not import time
from . import service as _service


class ShardUnavailableError(RuntimeError):
    """A shard's server process is gone (exited, crashed, or unreachable).

    ``args[0]`` names the shard and the failure detail — including, for
    deadline failures, how long the transport waited and how many retries
    it burned — so a caller can assert "timed out, N retries" without
    string-parsing logs."""

    def __init__(self, shard: int, detail: str):
        super().__init__(f"shard {shard} unavailable: {detail}")
        self.shard = shard
        self.detail = detail


# ---------------------------------------------------------------------- #
# worker spawn/reap helpers (shared by the out-of-process transports)
# ---------------------------------------------------------------------- #
def _worker_env() -> Dict[str, str]:
    """Environment for a spawned worker: it must resolve ``repro``
    exactly as this process does (__path__, not __file__: repro is a
    namespace package)."""
    env = dict(os.environ)
    import repro
    pkg_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env["PYTHONPATH"] = pkg_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def _reap(proc: Optional[subprocess.Popen], grace_s: float = 5.0) -> None:
    """Wait for a worker to exit, escalating to kill() on a stuck one;
    never raises, safe to call twice."""
    if proc is None:
        return
    try:
        proc.wait(timeout=grace_s)
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.wait()


class ShardClient(abc.ABC):
    """Typed client over one shard's ClusterService."""

    def __init__(self, shard_id: int = 0, obs: Obs = NULL_OBS):
        self.shard_id = shard_id
        #: the *coordinator's* Obs handle — wire spans and per-shard RPC
        #: metrics are client-side observations (the shard records its own
        #: server-side spans with its index's handle)
        self.obs = obs
        self.bytes_sent = 0
        self.bytes_received = 0
        self.round_trips = 0

    # ------------------------------------------------------------------ #
    @abc.abstractmethod
    def request(self, req: m.Message) -> m.Message:
        """One protocol round trip; raises the shard's exception natively."""

    def close(self) -> None:
        """Tear down the connection/worker; idempotent."""

    # ------------------------------------------------------------------ #
    # typed operations (the only shard surface ShardedIndex uses)
    # ------------------------------------------------------------------ #
    def hello(self) -> m.HelloResp:
        return self.request(m.HelloReq())

    def insert_batch(self, X: np.ndarray, ids: Sequence[int],
                     want_digest: bool = False
                     ) -> Tuple[List[int], Optional[np.ndarray]]:
        r = self.request(m.InsertBatchReq(X=X, ids=ids,
                                          want_digest=want_digest))
        return [int(i) for i in r.ids], r.digest

    def delete_batch(self, ids: Sequence[int]) -> None:
        self.request(m.DeleteBatchReq(ids=ids))

    def labels(self, ids=None) -> Dict[int, int]:
        r = self.request(m.LabelsReq(ids=None if ids is None else list(ids)))
        return {int(i): int(l) for i, l in zip(r.ids, r.labels)}

    def component_of(self, idx: int):
        """The shard's native component handle (opaque: an int or an
        Euler-tour node payload tuple, identical across transports)."""
        return m.decode_handle(self.request(m.ComponentOfReq(idx=int(idx))).value)

    def component_of_batch(self, ids: Sequence[int]) -> list:
        """Native component handles of ``ids``, one round trip."""
        r = self.request(m.ComponentOfBatchReq(ids=list(ids)))
        return [m.decode_handle(v) for v in r.values or []]

    def core_anchor_of(self, idx: int) -> Optional[int]:
        v = self.request(m.CoreAnchorOfReq(idx=int(idx))).value
        return None if v is None else int(v)

    def drain_deltas(self):
        r = self.request(m.DrainDeltasReq())
        if not r.tracked:
            return None
        return [] if r.deltas is None else m.decode_deltas(r.deltas)

    def ids(self) -> List[int]:
        return [int(i) for i in self.request(m.IdsReq()).ids]

    def stats(self) -> Tuple[Dict[str, int], int]:
        r = self.request(m.StatsReq())
        return dict(r.stats or {}), int(r.n_live)

    def pull_obs(self) -> Optional[dict]:
        """Drain the shard's server-side Obs payload (metrics snapshot +
        finished spans), or None when the shard is un-instrumented."""
        return self.request(m.StatsReq(want_obs=True)).obs

    def snapshot_state(self) -> Dict[str, np.ndarray]:
        return dict(self.request(m.SnapshotReq()).state or {})

    def restore(self, config: dict, state: Dict[str, np.ndarray]) -> None:
        self.request(m.RestoreReq(config=config, state=state))

    def check_invariants(self) -> None:
        self.request(m.CheckInvariantsReq())


class LocalTransport(ShardClient):
    """In-process shard: zero-copy dispatch straight into the service."""

    def __init__(self, cfg: ClusterConfig, shard_id: int = 0,
                 obs: Obs = NULL_OBS):
        super().__init__(shard_id, obs=obs)
        self.index = build_index(cfg)
        # label the in-process shard's own handle so its spans/metrics
        # land in a per-shard lane, matching the process transport
        self.index.obs.set_proc(f"shard{shard_id}")
        self.service = _service.ClusterService(self.index)
        # hot-path bindings: the sharded quotient build calls these
        # thousands of times per epoch — go straight to the engine, as the
        # pre-protocol code did (message objects would be pure overhead)
        self.component_of = self.index.component_of
        self.core_anchor_of = self.index.core_anchor_of

    def component_of_batch(self, ids):  # hot-path
        comp = self.index.component_of
        return [comp(int(i)) for i in ids]

    def request(self, req: m.Message) -> m.Message:
        self.round_trips += 1
        if self.obs.enabled:
            ctx = self.obs.tracer.context()
            if ctx is not None:
                req.trace_ctx = ctx
                resp = self.service.handle(req)
                if resp.span_summary:
                    self.obs.tracer.ingest(resp.span_summary)
                    resp.span_summary = None
                return resp
        return self.service.handle(req)

    @contextlib.contextmanager
    def _traced(self, op):
        """Shard-lane span for the zero-copy bulk ops: nothing crosses a
        wire here, but a traced run still renders the same
        coordinator -> shard tree as the process transport."""
        ctx = self.obs.tracer.context() if self.obs.enabled else None
        if ctx is None:
            yield
            return
        tr = self.index.obs.tracer
        with tr.adopt(ctx):
            with tr.span("shard." + op):
                yield
        self.obs.tracer.ingest(tr.drain_export())

    # bulk ops skip the message layer too: same arrays in, same dicts out
    def insert_batch(self, X, ids, want_digest=False):
        with self._traced("insert_batch"):
            out = self.index.insert_batch(X, ids=list(ids))
            return out, (self.service.digest(np.asarray(X, dtype=np.float64))
                         if want_digest else None)

    def delete_batch(self, ids):
        with self._traced("delete_batch"):
            self.index.delete_batch(list(ids))

    def labels(self, ids=None):
        with self._traced("labels"):
            return self.index.labels(ids)

    def drain_deltas(self):
        return self.index.drain_deltas()

    def ids(self):
        return self.index.ids()

    def stats(self):
        return self.index.stats(), len(self.index)

    def snapshot_state(self):
        return self.index.snapshot()["state"]

    def restore(self, config, state):
        self.index.restore({"config": dict(config), "state": dict(state)})

    def check_invariants(self):
        self.index.check_invariants()


class ProcessTransport(ShardClient):
    """Out-of-process shard: one spawned worker, one unix socket pair."""

    def __init__(self, cfg: ClusterConfig, shard_id: int = 0,
                 timeout: Optional[float] = None, obs: Obs = NULL_OBS):
        super().__init__(shard_id, obs=obs)
        self._cfg = cfg
        # per-op deadline: a wedged (not just dead) worker must surface
        # as ShardUnavailableError, never a hang
        self._timeout = float(cfg.rpc_timeout_s if timeout is None
                              else timeout)
        self._closed = False
        parent, child = socket.socketpair()
        try:
            self._proc = subprocess.Popen(
                [sys.executable, "-m", "repro.service.worker",
                 "--fd", str(child.fileno()),
                 "--config", json.dumps(cfg.to_dict()),
                 "--proc", f"shard{shard_id}"],
                pass_fds=(child.fileno(),), env=_worker_env())
        finally:
            child.close()
        parent.settimeout(self._timeout)
        self._sock: Optional[socket.socket] = parent

    # ------------------------------------------------------------------ #
    def _gone(self, detail: str) -> ShardUnavailableError:
        code = self._proc.poll()
        if code is not None:
            detail = f"worker exited with code {code} ({detail})"
        return ShardUnavailableError(self.shard_id, detail)

    def request(self, req: m.Message) -> m.Message:  # hot-path
        if not self.obs.enabled:
            return self._roundtrip(req)
        # traced round trip: a client-side wire span whose context rides
        # the request header; the worker's spans come back piggybacked on
        # the response and fold into this process's buffer
        tracer = self.obs.tracer
        with tracer.span(f"wire.shard{self.shard_id}", op=req.kind) as sp:
            req.trace_ctx = sp.wire_ctx()
            resp = self._roundtrip(req)
        if resp.span_summary:
            tracer.ingest(resp.span_summary)
            resp.span_summary = None
        return resp

    def _roundtrip(self, req: m.Message) -> m.Message:  # hot-path
        if self._sock is None:
            raise ShardUnavailableError(self.shard_id, "transport closed")
        try:
            self.bytes_sent += write_frame(self._sock, encode(req))
            payload = read_frame(self._sock)
        except socket.timeout as e:
            raise self._gone(
                f"request timed out after {self._timeout}s "
                f"(rpc_timeout_s), 0 retries") from e
        except (OSError, EOFError) as e:
            raise self._gone(str(e) or type(e).__name__) from e
        if payload is None:
            raise self._gone("connection closed by peer")
        self.bytes_received += len(payload) + 8
        self.round_trips += 1
        resp = decode(payload)
        if isinstance(resp, m.ErrorResp):
            raise _service.WIRE_ERRORS.get(resp.etype, RuntimeError)(resp.arg)
        return resp

    def close(self) -> None:
        """Shut the worker down; never raises, never hangs, and a second
        invocation is a no-op.  A worker that ignores the shutdown frame
        (or outlives the 5s grace period) is killed and reaped."""
        if self._closed:
            return
        self._closed = True
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.settimeout(5.0)
                write_frame(sock, encode(m.ShutdownReq()))
                read_frame(sock)
            except (OSError, EOFError):
                pass
            finally:
                sock.close()
        _reap(self._proc)

    def __del__(self):  # backstop: never leak worker processes
        try:
            self.close()
        except Exception:
            pass


class TcpTransport(ShardClient):
    """Shard over TCP: framed protocol + timeouts, retries, auth, dedup.

    The connection is an expendable resource: any send/receive failure —
    EOF, reset, or the per-op deadline (``cfg.rpc_timeout_s``) — drops
    the socket and the transport reconnects with exponential backoff, up
    to ``retries`` times.  Each (re)connect runs the hello handshake:
    token auth plus the dedup exchange, where the server echoes the
    highest op-sequence number it has applied for this client.  Idempotent
    requests are simply re-sent; mutations are re-sent with their original
    ``op_seq`` header, so a mutation that *did* land before the connection
    died is answered from the server's dedup cache instead of applying
    twice — exactly-once, not at-least-once.

    With ``addr=None`` the transport spawns its own worker on
    ``127.0.0.1`` (ephemeral port, fresh auth token) — the local-fleet
    configuration the coordinator uses.  Pass ``addr=(host, port)`` and
    the worker's ``token`` to reach a shard served elsewhere; the
    transport then owns only the connection, not the process.
    """

    RETRIES = 3           # reconnect attempts after the first failure
    BACKOFF_S = 0.05      # first backoff; doubles per retry
    BACKOFF_MAX_S = 1.0
    CONNECT_TIMEOUT_S = 5.0

    def __init__(self, cfg: ClusterConfig, shard_id: int = 0,
                 obs: Obs = NULL_OBS,
                 addr: Optional[Tuple[str, int]] = None,
                 token: Optional[str] = None,
                 retries: Optional[int] = None,
                 die_after: int = 0):
        super().__init__(shard_id, obs=obs)
        self._cfg = cfg
        self._timeout = float(cfg.rpc_timeout_s)
        self._retries = self.RETRIES if retries is None else int(retries)
        # dedup identity: unique per client *instance* — a respawned
        # coordinator is a new client with a fresh sequence space
        self._client_id = f"{os.getpid():x}.{secrets.token_hex(4)}.s{shard_id}"
        self._next_seq = 0
        self._server_last_seq = -1
        self._closed = False
        self._sock: Optional[socket.socket] = None
        self._proc: Optional[subprocess.Popen] = None
        # bound once so the counter appears (at zero) in any instrumented
        # snapshot — the fleet dashboards key on it existing
        self._c_retries = obs.counter("rpc.retries")
        self._c_reconnects = obs.counter("rpc.reconnects")
        if addr is None:
            token = token or secrets.token_hex(16)
            self._proc, addr = self._spawn(cfg, shard_id, token, die_after)
        self._addr = addr
        self._token = token
        self._connect()

    # ------------------------------------------------------------------ #
    @staticmethod
    def _spawn(cfg: ClusterConfig, shard_id: int, token: str,
               die_after: int) -> Tuple[subprocess.Popen, Tuple[str, int]]:
        """Spawn a TCP worker on an ephemeral port and learn the port
        from its WORKER_PORT announcement."""
        args = [sys.executable, "-m", "repro.service.worker",
                "--listen", "127.0.0.1:0",
                "--config", json.dumps(cfg.to_dict()),
                "--proc", f"shard{shard_id}",
                "--token", token]
        if die_after > 0:
            args += ["--die-after", str(die_after)]
        proc = subprocess.Popen(args, env=_worker_env(),
                                stdout=subprocess.PIPE, text=True)
        line = proc.stdout.readline() if proc.stdout else ""
        if not line.startswith("WORKER_PORT="):
            _reap(proc)
            raise ShardUnavailableError(
                shard_id, "worker failed to start (no port announcement; "
                          f"exit code {proc.poll()})")
        return proc, ("127.0.0.1", int(line.split("=", 1)[1]))

    def _disconnect(self) -> None:
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    def _connect(self) -> None:
        """Dial + authenticate + dedup handshake; raises OSError/EOFError
        on connection trouble (retryable) and PermissionError on an auth
        reject (not retryable — a bad token will not heal)."""
        sock = socket.create_connection(self._addr,
                                        timeout=self.CONNECT_TIMEOUT_S)
        sock.settimeout(self._timeout)
        self._sock = sock
        try:
            hello = self._exchange(m.HelloReq(token=self._token,
                                              client_id=self._client_id))
        except BaseException:
            self._disconnect()
            raise
        self._server_last_seq = int(hello.last_seq)

    def _exchange(self, req: m.Message) -> m.Message:
        """One frame each way on the live socket; no retry logic here."""
        self.bytes_sent += write_frame(self._sock, encode(req))
        payload = read_frame(self._sock)
        if payload is None:
            raise EOFError("connection closed by peer")
        self.bytes_received += len(payload) + 8
        self.round_trips += 1
        resp = decode(payload)
        if isinstance(resp, m.ErrorResp):
            raise _service.WIRE_ERRORS.get(resp.etype, RuntimeError)(resp.arg)
        return resp

    # ------------------------------------------------------------------ #
    def request(self, req: m.Message) -> m.Message:  # hot-path
        # stamp mutations once — retries re-send the identical header, so
        # the server can collapse duplicate deliveries
        if req.kind in m.MUTATION_KINDS and req.op_seq is None:
            req.op_seq = (self._client_id, self._next_seq)
            self._next_seq += 1
        if not self.obs.enabled:
            return self._request_with_retries(req)
        tracer = self.obs.tracer
        with tracer.span(f"wire.shard{self.shard_id}", op=req.kind) as sp:
            req.trace_ctx = sp.wire_ctx()
            resp = self._request_with_retries(req)
        if resp.span_summary:
            tracer.ingest(resp.span_summary)
            resp.span_summary = None
        return resp

    def _request_with_retries(self, req: m.Message) -> m.Message:
        if self._closed:
            raise ShardUnavailableError(self.shard_id, "transport closed")
        attempts = 0
        while True:
            try:
                if self._sock is None:
                    self._c_reconnects.inc()
                    self._connect()
                return self._exchange(req)
            except socket.timeout as e:
                self._disconnect()
                attempts += 1
                self._fail_or_backoff(
                    attempts, f"request timed out after {self._timeout}s",
                    e)
            except (OSError, EOFError) as e:
                self._disconnect()
                attempts += 1
                self._fail_or_backoff(attempts,
                                      str(e) or type(e).__name__, e)

    def _fail_or_backoff(self, attempts: int, what: str,
                         cause: BaseException) -> None:
        """Give up with a named, detailed ShardUnavailableError — or
        sleep the backoff and let the caller loop retry."""
        proc = self._proc
        if proc is not None and proc.poll() is not None:
            # the worker itself is gone: reconnecting cannot succeed,
            # fail fast instead of burning the retry budget
            raise ShardUnavailableError(
                self.shard_id,
                f"worker exited with code {proc.poll()} ({what}, "
                f"{attempts - 1} retries)") from cause
        if attempts > self._retries:
            raise ShardUnavailableError(
                self.shard_id,
                f"{what}; gave up after {attempts} attempts "
                f"({attempts - 1} retries, "
                f"rpc_timeout_s={self._timeout})") from cause
        self._c_retries.inc()
        time.sleep(min(self.BACKOFF_S * (2 ** (attempts - 1)),
                       self.BACKOFF_MAX_S))

    # ------------------------------------------------------------------ #
    def close(self) -> None:
        """Shut down the connection (and the worker, if this transport
        spawned it); idempotent, never raises, never hangs."""
        if self._closed:
            return
        self._closed = True
        sock, self._sock = self._sock, None
        if sock is not None:
            if self._proc is not None:  # we own the worker: ask it to exit
                try:
                    sock.settimeout(5.0)
                    write_frame(sock, encode(m.ShutdownReq()))
                    read_frame(sock)
                except (OSError, EOFError):
                    pass
            try:
                sock.close()
            except OSError:
                pass
        if self._proc is not None:
            if self._proc.stdout:
                self._proc.stdout.close()
            _reap(self._proc)

    def __del__(self):  # backstop: never leak worker processes
        try:
            self.close()
        except Exception:
            pass


TRANSPORTS = {"local": LocalTransport, "process": ProcessTransport,
              "tcp": TcpTransport}


def connect_shards(inner_cfg: ClusterConfig, n_shards: int,
                   transport: str, obs: Obs = NULL_OBS) -> List[ShardClient]:
    """Build/spawn one ShardClient per shard for ``transport``; ``obs``
    is the coordinator's handle (client-side wire spans/metrics)."""
    try:
        factory = TRANSPORTS[transport]
    except KeyError:
        raise ValueError(
            f"unknown transport {transport!r} "
            f"(expected one of {', '.join(sorted(TRANSPORTS))})") from None
    clients: List[ShardClient] = []
    try:
        for s in range(n_shards):
            clients.append(factory(inner_cfg, shard_id=s, obs=obs))
    except Exception:
        for c in clients:
            c.close()
        raise
    return clients
