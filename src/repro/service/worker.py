"""Shard server process: ``python -m repro.service.worker``.

Spawned by :class:`~repro.service.transport.ProcessTransport` with an
inherited socket fd and the shard's inner ClusterConfig as JSON; builds
the index, serves the frame loop until shutdown/EOF, exits.  Runnable by
hand against any socket fd for debugging.
"""

from __future__ import annotations

import argparse
import json
import socket
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fd", type=int, required=True,
                    help="inherited stream-socket file descriptor")
    ap.add_argument("--config", required=True,
                    help="ClusterConfig of the served index, as JSON")
    ap.add_argument("--proc", default=None,
                    help="observability process label (e.g. 'shard3'); "
                         "names this worker's lane in trace dumps")
    args = ap.parse_args(argv)

    # import late: argparse errors shouldn't cost a numpy import
    from ..api import ClusterConfig, build_index
    from .service import ClusterService, serve_connection

    cfg = ClusterConfig.from_dict(json.loads(args.config))
    index = build_index(cfg)
    if args.proc:
        index.obs.set_proc(args.proc)
    sock = socket.socket(fileno=args.fd)
    try:
        serve_connection(ClusterService(index), sock)
    finally:
        sock.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
