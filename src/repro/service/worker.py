"""Shard server process: ``python -m repro.service.worker``.

Spawned by :class:`~repro.service.transport.ProcessTransport` with an
inherited socket fd (``--fd``), or by
:class:`~repro.service.transport.TcpTransport` as a TCP listener
(``--listen HOST:PORT``; port 0 binds an ephemeral port and the worker
prints ``WORKER_PORT=<port>`` on stdout so the spawner can connect).
Either way it builds the index from the shard's inner ClusterConfig
(JSON) and serves the frame loop until ShutdownReq; in listener mode a
client disconnect only ends that *connection* — the worker keeps
accepting, so a retrying client can reconnect after a network blip
without losing shard state.  Connections are served on threads (so a
reconnecting client is never stuck behind a half-dead predecessor in the
accept queue) but requests are serialised through one lock: the engine
itself stays single-threaded, matching the one-worker-per-shard rule.

``--token`` requires every connection to authenticate with a matching
HelloReq before any other request is served.  ``--die-after N`` is the
fault-injection knob: the worker hard-exits (``os._exit(1)``) upon
receiving its Nth request, before handling it — the client observes a
mid-request EOF, exactly what a crash looks like.  Runnable by hand
against any socket fd or port for debugging.
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import sys
import threading


class CrashAfter:
    """Fault injection: pass through ``handle`` for the first ``n - 1``
    requests, then hard-exit on the Nth *before* handling it."""

    def __init__(self, service, n: int):
        self._service = service
        self._left = int(n)

    def handle(self, req):
        self._left -= 1
        if self._left < 0:
            os._exit(1)  # simulated crash: no response, no cleanup
        return self._service.handle(req)


class Serialized:
    """One lock in front of ``handle``: listener mode accepts concurrent
    connections, but the engine only ever sees one request at a time."""

    def __init__(self, service):
        self._service = service
        self._lock = threading.Lock()

    def handle(self, req):
        with self._lock:
            return self._service.handle(req)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fd", type=int, default=None,
                    help="inherited stream-socket file descriptor")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="serve over TCP instead of an inherited fd; "
                         "port 0 binds an ephemeral port, printed as "
                         "WORKER_PORT=<port> on stdout")
    ap.add_argument("--config", required=True,
                    help="ClusterConfig of the served index, as JSON")
    ap.add_argument("--proc", default=None,
                    help="observability process label (e.g. 'shard3'); "
                         "names this worker's lane in trace dumps")
    ap.add_argument("--token", default=None,
                    help="require connections to authenticate with this "
                         "token on their first HelloReq")
    ap.add_argument("--die-after", type=int, default=0, dest="die_after",
                    metavar="N",
                    help="fault injection: hard-exit upon receiving the "
                         "Nth request (0 = never)")
    args = ap.parse_args(argv)
    if (args.fd is None) == (args.listen is None):
        ap.error("exactly one of --fd / --listen is required")

    # import late: argparse errors shouldn't cost a numpy import
    from ..api import ClusterConfig, build_index
    from .service import ClusterService, serve_connection

    cfg = ClusterConfig.from_dict(json.loads(args.config))
    index = build_index(cfg)
    if args.proc:
        index.obs.set_proc(args.proc)
    service = ClusterService(index)
    if args.die_after > 0:
        service = CrashAfter(service, args.die_after)

    if args.fd is not None:
        sock = socket.socket(fileno=args.fd)
        try:
            serve_connection(service, sock, auth_token=args.token)
        finally:
            sock.close()
        return 0

    host, _, port = args.listen.rpartition(":")
    srv = socket.create_server((host or "127.0.0.1", int(port)))
    # announce the bound port before the first accept — the spawner
    # blocks on this line, so it must go out even under port 0
    print(f"WORKER_PORT={srv.getsockname()[1]}", flush=True)
    service = Serialized(service)
    stop = threading.Event()

    def serve(conn: socket.socket) -> None:
        try:
            if serve_connection(service, conn, auth_token=args.token):
                stop.set()
        finally:
            conn.close()

    # timeout-polled accept: closing a listener from another thread does
    # not reliably wake a blocked accept(), so the loop re-checks the
    # stop flag a few times a second instead
    srv.settimeout(0.25)
    try:
        while not stop.is_set():
            try:
                conn, _addr = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            conn.settimeout(None)
            threading.Thread(target=serve, args=(conn,),
                             daemon=True).start()
    finally:
        srv.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
