"""repro.shard — sharded ClusterIndex with LSH key-range routing.

    from repro.api import ClusterConfig, build_index

    index = build_index(ClusterConfig(d=8, k=10, t=10, eps=0.5,
                                      backend="sharded", shards=4,
                                      inner_backend="batched",
                                      workers=4))          # threaded fan-out

Everything downstream of ``build_index`` (serving, curation, examples,
benchmarks) gets sharding for free; see :mod:`repro.shard.index` for the
architecture (router / shard clients / boundary bridge).  ``label()`` is
an incremental point query (inner-find -> bridge-find over the maintained
boundary-bucket set) unless ``incremental_merge=False`` restores the
rebuild-per-query merge.  ``transport="process"`` runs each shard as a
spawned server process behind the :mod:`repro.service` wire protocol —
bit-identical results, GIL-free update fan-out.
"""

from ..api.config import ClusterConfig
from ..api.registry import register_backend
from .bridge import BoundaryBridge  # noqa: F401
from .index import ShardedIndex  # noqa: F401
from .rebalance import propose_rebalance, shard_loads  # noqa: F401
from .router import SLOTS, RebalancePlan, ShardRouter  # noqa: F401


@register_backend("sharded")
def _build_sharded(cfg: ClusterConfig) -> ShardedIndex:
    return ShardedIndex(cfg)
