"""LSH key-range routing: table-0 grid code -> slot -> shard.

The paper's grid LSH (Definition 3) already assigns every point a
deterministic integer code vector per table, so the partitioning key for
sharding exists for free: we hash the *table-0* code into a small slot
space (``SLOTS`` = 4096) and assign contiguous slot ranges to shards.
Ranges (not a bare modulus) are the unit of ownership so that rebalancing
is a key-range move — the same primitive a multi-host deployment would
ship between workers.

Routing is placement only: clustering correctness never depends on which
shard a point lands in (the boundary bridge reconciles cross-shard
structure), so the slot may be derived from either key representation.
With ``mixed=True`` the router slots points by the *table-0 mixed key*
(the float32 device-hash pass), so a sharded index over a mixed-key inner
engine runs exactly one hash pass per batch — the same pass that produces
the inner bucket keys — instead of paying a second exact-code pass just
for routing.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.hashing import GridLSH

SLOTS = 1 << 12  # granularity of the key space (ranges are slot intervals)

_SM_A = np.uint64(0xBF58476D1CE4E5B9)  # splitmix64 finalizer constants
_SM_B = np.uint64(0x94D049BB133111EB)


def _splitmix_slots(h: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer -> (n,) slot ids; the one mixing pipeline
    both key families share, so their slot hashes can never diverge."""
    with np.errstate(over="ignore"):
        h ^= h >> np.uint64(30)
        h *= _SM_A
        h ^= h >> np.uint64(27)
        h *= _SM_B
        h ^= h >> np.uint64(31)
    return (h & np.uint64(SLOTS - 1)).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class RebalancePlan:
    """Move the slot range ``[start, stop)`` to shard ``target``."""

    start: int
    stop: int
    target: int


class ShardRouter:
    """Deterministic point -> shard assignment over ``SLOTS`` key slots."""

    def __init__(self, lsh: GridLSH, n_shards: int, seed: int = 0,
                 assignment: Optional[np.ndarray] = None,
                 mixed: bool = False):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.lsh = lsh
        self.n_shards = int(n_shards)
        self.mixed = bool(mixed)  # slot by table-0 mixed key, not exact code
        # per-dimension odd multipliers for the slot hash, derived from the
        # config seed (stable across processes, unlike hash(bytes))
        rng = np.random.default_rng([int(seed) & 0x7FFFFFFF, 0x51A2D])
        self._mult = (
            rng.integers(1, 2**63 - 1, size=lsh.d, dtype=np.int64)
            .astype(np.uint64) | np.uint64(1)
        )
        if assignment is None:
            # even contiguous ranges: slot s belongs to shard s*S // SLOTS
            assignment = (np.arange(SLOTS, dtype=np.int64)
                          * n_shards) // SLOTS
        assignment = np.asarray(assignment, dtype=np.int64)
        if assignment.shape != (SLOTS,):
            raise ValueError(f"assignment shape {assignment.shape} != ({SLOTS},)")
        if assignment.min() < 0 or assignment.max() >= n_shards:
            raise ValueError("assignment references an unknown shard")
        self.assignment = assignment

    # ------------------------------------------------------------------ #
    # routing
    # ------------------------------------------------------------------ #
    def slots_batch(self, X: np.ndarray) -> np.ndarray:
        """(n, d) points -> (n,) key slots via splitmix64 of the table-0
        key (one vectorised pass, no per-point hashing).  Uses whichever
        key family this router was built for, so every caller — insert
        routing, rebalance planning, load inspection — slots a given
        point identically."""
        X = np.asarray(X, dtype=np.float64)
        if self.mixed:
            return self.slots_from_mixed(self.lsh.device_keys_batch(X)[:, 0, :])
        return self.slots_from_codes(self.lsh.codes_batch(X)[:, 0, :])

    def slots_from_mixed(self, m0: np.ndarray) -> np.ndarray:
        """(n, 2) table-0 int32 mixed keys -> (n,) key slots (callers that
        already ran ``device_keys_batch`` skip the second hashing pass)."""
        m = (np.asarray(m0, dtype=np.int64).reshape(-1, 2)
             & np.int64(0xFFFFFFFF)).astype(np.uint64)
        with np.errstate(over="ignore"):
            h = (m[:, 0] << np.uint64(32)) | m[:, 1]
            h *= self._mult[0]  # seed-dependent pre-mix, then splitmix64
        return _splitmix_slots(h)

    def slots_from_codes(self, c0: np.ndarray) -> np.ndarray:
        """(n, d) table-0 int64 grid codes -> (n,) key slots (callers that
        already ran ``codes_batch`` skip the second hashing pass)."""
        c0 = np.asarray(c0, dtype=np.int64).astype(np.uint64)  # (n, d)
        with np.errstate(over="ignore"):
            h = (c0 * self._mult[None, :]).sum(axis=1, dtype=np.uint64)
        return _splitmix_slots(h)

    def shards_batch(self, X: np.ndarray) -> np.ndarray:
        """(n, d) points -> (n,) shard ids."""
        return self.assignment[self.slots_batch(X)]

    def shard_of(self, x: np.ndarray) -> int:
        return int(self.shards_batch(np.asarray(x)[None])[0])

    # ------------------------------------------------------------------ #
    # key-range bookkeeping
    # ------------------------------------------------------------------ #
    def ranges(self) -> List[Tuple[int, int, int]]:
        """Contiguous runs of the assignment as (start, stop, shard)."""
        out = []
        start = 0
        for s in range(1, SLOTS + 1):
            if s == SLOTS or self.assignment[s] != self.assignment[start]:
                out.append((start, s, int(self.assignment[start])))
                start = s
        return out

    def move_range(self, plan: RebalancePlan) -> None:
        """Reassign slots [start, stop) to ``plan.target``."""
        if not (0 <= plan.start < plan.stop <= SLOTS):
            raise ValueError(f"slot range [{plan.start}, {plan.stop}) "
                             f"outside [0, {SLOTS})")
        if not (0 <= plan.target < self.n_shards):
            raise ValueError(f"target shard {plan.target} outside "
                             f"[0, {self.n_shards})")
        self.assignment[plan.start:plan.stop] = plan.target

    def slot_loads(self, slots: np.ndarray) -> np.ndarray:
        """(m,) observed point slots -> (SLOTS,) occupancy histogram."""
        return np.bincount(np.asarray(slots, dtype=np.int64),
                           minlength=SLOTS)

    @staticmethod
    def load_skew(sizes: "List[int]") -> float:
        """Key-range skew of observed per-shard occupancy: max over mean
        (1.0 = perfectly balanced; 0.0 for an empty index).  The gauge the
        observability layer and rebalance planning read."""
        total = sum(sizes)
        if not sizes or total == 0:
            return 0.0
        return max(sizes) * len(sizes) / total

    # ------------------------------------------------------------------ #
    # persistence
    # ------------------------------------------------------------------ #
    def state(self) -> np.ndarray:
        return self.assignment.copy()

    def load_state(self, assignment: np.ndarray) -> None:
        assignment = np.asarray(assignment, dtype=np.int64)
        if assignment.shape != (SLOTS,):
            raise ValueError(f"assignment shape {assignment.shape} != ({SLOTS},)")
        self.assignment = assignment
