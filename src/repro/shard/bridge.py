"""Boundary bridge: cross-shard cluster merging over the collision graph.

A shard's inner index only sees its own points, so two global facts are
invisible to it:

  * **support** — Definition 4 is global: a bucket with ``k`` members
    split across shards makes all of them core, while every local bucket
    stays sub-threshold;
  * **connectivity** — core points sharing a bucket are one cluster even
    when they live on different shards (and border points may have their
    only colliding core on a remote shard).

The bridge keeps a directory of the *global* buckets — membership,
per-shard occupancy, exact global **and local** support counts (the same
threshold-crossing bookkeeping DynamicDBSCAN does, minus the forest).

The key structural fact (the cell-graph locality argument of de Berg et
al., and the merge step of Wang–Gu–Shun's parallel DBSCAN): the inner
engines already maintain exact intra-shard connectivity under updates —
their Euler-tour forests chain the *locally core* members of every
bucket.  The only buckets whose collision edges the local forests can
miss are the **interesting** ones:

  * buckets whose members span more than one shard, or
  * buckets holding a *boundary core* — a point that is globally core
    (Definition 4 over the global bucket) but locally sub-threshold, so
    its home shard never chained it.

``incremental=True`` (default) maintains, under ``insert`` / ``delete``
/ ``move``, exactly this boundary-bucket set plus per-bucket merge
*representatives*: one locally-core core per (bucket, shard) — all
locally-core cores of a bucket on one shard are already one inner
component, so one stands in for all — and the bucket's boundary cores.
Insertions and promotions extend these eagerly through the touched
buckets and threshold crossings; deletions and demotions shrink or
re-mark them (a dead cached representative is repaired lazily).  Every
mutation stamps an epoch; the first query of an epoch builds a small
quotient union-find by chaining each interesting bucket's
representatives through their *current* inner component handles
(inner-find = Euler-tour ROOT) — O(boundary), not O(n) — and
``resolve()`` is then one inner find plus one quotient find.
``labels()`` reuses the per-shard labellings and chains only the
interesting buckets.

``incremental=False`` restores the PR-2 path: :meth:`merge` rebuilds a
throwaway union-find over *all* live points and scans the whole
directory on every call (kept as the oracle and fallback).

Equivalence caveat (shared with the repo's cross-backend equivalence in
general): which cluster a *border* point joins is a tie-break.  When a
non-core point collides with cores of two different clusters, the
single-shard engine keeps whichever anchor its update history produced,
while the merge keeps the shard-local anchor (or scans tables in order
for a remote one) — the core partition and the noise set always match,
but such a border point can land in the other colliding cluster.  The
paper's well-separated workloads never exercise the tie.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple

from ..core.dynamic_dbscan import NOISE
from ..obs import NULL_OBS, Obs

BucketKey = Tuple[int, bytes]  # (table, key bytes)

# merge-representative classes of a live point w.r.t. one of its buckets
_NONCORE, _LOCAL_CORE, _BOUNDARY_CORE = 0, 1, 2


class _Reps:
    """Merge representatives of one bucket: per-shard locally-core count
    and cached representative (None = stale, repaired lazily), plus the
    bucket's boundary cores."""

    __slots__ = ("lc_count", "lc_rep", "bc")

    def __init__(self):
        self.lc_count: Dict[int, int] = {}
        self.lc_rep: Dict[int, Optional[int]] = {}
        self.bc: Set[int] = set()

    def units(self) -> int:
        return len(self.lc_count) + len(self.bc)


class BoundaryBridge:
    def __init__(self, t: int, k: int, attach_orphans: bool = True,
                 incremental: bool = True, obs: Obs = NULL_OBS,
                 core_eligible: Optional[Callable[[int], bool]] = None):
        self.t, self.k = int(t), int(k)
        self.attach_orphans = attach_orphans
        self.incremental = bool(incremental)
        self.obs = obs
        # Sampled-core mode (inner_backend="approx"): only points passing
        # this predicate can gain support, and the threshold tests run on
        # eligible-member counts (n_elig / elig_sc) instead of raw bucket
        # sizes — mirroring SampledCoreDBSCAN's _ssize.  None = exact:
        # the eligible structures stay empty and every test reads the raw
        # counts, so the exact path pays nothing.
        self.core_eligible = core_eligible
        self.elig: Dict[int, bool] = {}  # predicate memoised per live id
        self.n_elig: Dict[BucketKey, int] = {}
        self.elig_sc: Dict[BucketKey, Dict[int, int]] = {}
        # instruments bound once (no-ops when un-instrumented); the
        # rep-cache counters split the lazy-repair bookkeeping into the
        # hit/miss view the observability report wants
        self._h_quotient_us = obs.histogram("bridge.quotient_us")
        self._h_merge_us = obs.histogram("bridge.merge_us")
        self._c_q_hit = obs.counter("bridge.quotient_cache_hit")
        self._c_q_miss = obs.counter("bridge.quotient_cache_miss")
        self._c_rep_hit = obs.counter("bridge.rep_cache_hit")
        self._c_rep_miss = obs.counter("bridge.rep_cache_miss")
        self.members: Dict[BucketKey, Set[int]] = {}
        self.shard_count: Dict[BucketKey, Dict[int, int]] = {}
        self.keys: Dict[int, List[bytes]] = {}
        self.support: Dict[int, int] = {}  # #buckets of size >= k (global)
        self.n_boundary_buckets = 0  # buckets whose members span >1 shard
        self.n_merge_passes = 0
        self.n_bridge_unions = 0
        # --- incremental boundary structure (see module docstring) ---
        self.home: Dict[int, int] = {}           # idx -> shard
        self.local_support: Dict[int, int] = {}  # #buckets locally >= k
        self.n_cores: Dict[BucketKey, int] = {}  # global cores per bucket
        self._rep: Dict[BucketKey, int] = {}     # cached live core per bucket
        self._reps: Dict[BucketKey, _Reps] = {}  # merge representatives
        self.interesting: Set[BucketKey] = set()
        self.epoch = 0  # bumped per mutation; quotient is epoch-stamped
        self._q_parent: Dict[int, int] = {}
        self._q_epoch = -1
        self.n_quotient_builds = 0
        self.n_boundary_merges = 0
        self.n_rep_repairs = 0

    # ------------------------------------------------------------------ #
    # directory maintenance (mirrors DynamicDBSCAN's support bookkeeping)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _cls(sup: int, loc: int) -> int:
        if sup <= 0:
            return _NONCORE
        return _BOUNDARY_CORE if loc == 0 else _LOCAL_CORE

    def _refresh_interesting(self, b: BucketKey) -> None:
        ent = self._reps.get(b)
        if b in self.members and (len(self.shard_count[b]) > 1
                                  or (ent is not None and ent.bc)):
            self.interesting.add(b)
        else:
            self.interesting.discard(b)

    def _rep_add(self, b: BucketKey, m: int, cls: int, shard: int) -> None:
        if cls == _NONCORE:
            return
        ent = self._reps.get(b)
        if ent is None:
            ent = self._reps[b] = _Reps()
        if cls == _BOUNDARY_CORE:
            ent.bc.add(m)
        else:
            ent.lc_count[shard] = ent.lc_count.get(shard, 0) + 1
            if ent.lc_rep.get(shard) is None:
                ent.lc_rep[shard] = m

    def _rep_remove(self, b: BucketKey, m: int, cls: int, shard: int) -> None:
        if cls == _NONCORE:
            return
        ent = self._reps[b]
        if cls == _BOUNDARY_CORE:
            ent.bc.discard(m)
        else:
            n = ent.lc_count[shard] - 1
            if n:
                ent.lc_count[shard] = n
                if ent.lc_rep.get(shard) == m:
                    ent.lc_rep[shard] = None  # stale; repaired lazily
            else:
                del ent.lc_count[shard]
                ent.lc_rep.pop(shard, None)
        if not ent.lc_count and not ent.bc:
            del self._reps[b]

    def _lc_rep_of(self, b: BucketKey, shard: int) -> int:
        """The (bucket, shard) locally-core representative, re-scanned
        only when the cached one was removed."""
        ent = self._reps[b]
        m = ent.lc_rep.get(shard)
        if m is not None:
            self._c_rep_hit.inc()
            return m
        self.n_rep_repairs += 1
        self._c_rep_miss.inc()
        for y in self.members[b]:
            if (self.home[y] == shard and self.support[y] > 0
                    and self.local_support[y] > 0):
                m = y
                break
        assert m is not None, (b, shard)
        ent.lc_rep[shard] = m
        return m

    def _pre(self, pre: Dict[int, Tuple[int, int]], m: int) -> None:
        if m not in pre:
            pre[m] = (self.support[m], self.local_support[m])

    def _apply_transitions(self, pre: Dict[int, Tuple[int, int]],
                           skip: Optional[int] = None) -> None:
        """Re-class every touched point and migrate it between the
        per-bucket representative structures."""
        for m, (sup0, loc0) in pre.items():
            if m == skip:
                continue
            c0 = self._cls(sup0, loc0)
            c1 = self._cls(self.support[m], self.local_support[m])
            if c0 == c1:
                continue
            s = self.home[m]
            for i, key in enumerate(self.keys[m]):
                b = (i, key)
                self._rep_remove(b, m, c0, s)
                self._rep_add(b, m, c1, s)
                self._refresh_interesting(b)

    def insert(self, idx: int, keys: List[bytes], shard: int) -> None:
        if idx in self.keys:
            raise KeyError(f"index {idx} already present in bridge directory")
        inc = self.incremental
        pred = self.core_eligible
        e_idx = True if pred is None else bool(pred(idx))
        if pred is not None:
            self.elig[idx] = e_idx
        self.keys[idx] = keys
        self.support[idx] = 0
        self.home[idx] = shard
        self.local_support[idx] = 0
        promoted: Set[int] = set()
        pre: Dict[int, Tuple[int, int]] = {}
        for i, key in enumerate(keys):
            b = (i, key)
            mem = self.members.setdefault(b, set())
            mem.add(idx)
            sc = self.shard_count.setdefault(b, {})
            sc[shard] = sc.get(shard, 0) + 1
            if sc[shard] == 1 and len(sc) == 2:
                self.n_boundary_buckets += 1
            # threshold tests run on eligible counts; a non-eligible
            # arrival changes no count, so no crossing is possible
            if pred is None:
                sz, loc_sz = len(mem), sc[shard]
            elif e_idx:
                sz = self.n_elig[b] = self.n_elig.get(b, 0) + 1
                es = self.elig_sc.setdefault(b, {})
                loc_sz = es[shard] = es.get(shard, 0) + 1
            else:
                sz = loc_sz = 0
            if sz == self.k:
                for y in mem:
                    if pred is not None and not self.elig[y]:
                        continue
                    if inc:
                        self._pre(pre, y)
                    self.support[y] += 1
                    if self.support[y] == 1:
                        promoted.add(y)
            elif sz > self.k:
                if inc:
                    self._pre(pre, idx)
                self.support[idx] += 1
            if not inc:
                continue
            # local threshold crossing: members homed on this shard gain
            # local support (their home forest now chains this bucket)
            if loc_sz == self.k:
                for y in mem:
                    if self.home[y] == shard and (pred is None
                                                  or self.elig[y]):
                        self._pre(pre, y)
                        self.local_support[y] += 1
            elif loc_sz > self.k:
                self._pre(pre, idx)
                self.local_support[idx] += 1
            self._refresh_interesting(b)
        if not inc:
            return
        if self.support[idx] > 0:  # core on arrival via sz > k buckets
            promoted.add(idx)
        for p in promoted:
            for i, key in enumerate(self.keys[p]):
                b = (i, key)
                self.n_cores[b] = self.n_cores.get(b, 0) + 1
                self._rep.setdefault(b, p)
        # idx's own status was seeded as (0, 0); transition it like the rest
        pre.setdefault(idx, (0, 0))
        self._apply_transitions(pre)
        self.epoch += 1

    def delete(self, idx: int, shard: int) -> None:
        if idx not in self.keys:
            raise KeyError(
                f"cannot delete index {idx}: not in bridge directory")
        inc = self.incremental
        pred = self.core_eligible
        e_idx = True if pred is None else self.elig[idx]
        was_core = self.support[idx] > 0
        cls_idx = (self._cls(self.support[idx], self.local_support[idx])
                   if inc else _NONCORE)
        demoted: List[int] = []
        pre: Dict[int, Tuple[int, int]] = {}
        for i, key in enumerate(self.keys[idx]):
            b = (i, key)
            mem = self.members[b]
            mem.discard(idx)
            sc = self.shard_count[b]
            sc[shard] -= 1
            if sc[shard] == 0:
                del sc[shard]
                if len(sc) == 1:
                    self.n_boundary_buckets -= 1
            # a non-eligible departure changes no eligible count: no
            # crossing possible
            if pred is None:
                crossed = len(mem) == self.k - 1
                loc_sz = sc.get(shard, 0)
            elif e_idx:
                ne = self.n_elig[b] - 1
                if ne:
                    self.n_elig[b] = ne
                else:
                    del self.n_elig[b]
                crossed = ne == self.k - 1
                es = self.elig_sc[b]
                es[shard] -= 1
                if es[shard] == 0:
                    del es[shard]
                    if not es:
                        del self.elig_sc[b]
                loc_sz = es.get(shard, 0)
            else:
                crossed = False
                loc_sz = self.k  # sentinel: no local crossing either
            if crossed:
                for y in mem:
                    if pred is not None and not self.elig[y]:
                        continue
                    if inc:
                        self._pre(pre, y)
                    self.support[y] -= 1
                    if self.support[y] == 0:
                        demoted.append(y)
            if inc:
                self._rep_remove(b, idx, cls_idx, shard)
                if was_core:
                    self._drop_core_from(b)
                # local threshold crossing on the vacated shard
                if loc_sz == self.k - 1:
                    for y in mem:
                        if self.home[y] == shard and (pred is None
                                                      or self.elig[y]):
                            self._pre(pre, y)
                            self.local_support[y] -= 1
            if not mem:
                del self.members[b]
                del self.shard_count[b]
                self.n_cores.pop(b, None)
                self._rep.pop(b, None)
                self._reps.pop(b, None)
                self.n_elig.pop(b, None)
                self.elig_sc.pop(b, None)
            if inc:
                self._refresh_interesting(b)
        if inc:
            for p in demoted:
                for i, key in enumerate(self.keys[p]):
                    self._drop_core_from((i, key))
        del self.keys[idx]
        del self.support[idx]
        if pred is not None:
            del self.elig[idx]
        if inc:
            del self.home[idx]
            del self.local_support[idx]
            self._apply_transitions(pre, skip=idx)
            self.epoch += 1

    def move(self, idx: int, src: int, dst: int) -> None:
        """Re-home ``idx`` (rebalance): membership and global support are
        placement-invariant; per-shard occupancy — and with it local
        support and the boundary-bucket set — shifts between ``src`` and
        ``dst``."""
        if idx not in self.keys:
            raise KeyError(f"cannot move index {idx}: not in bridge directory")
        if src == dst:
            return
        inc = self.incremental
        pre: Dict[int, Tuple[int, int]] = {}
        if inc:
            # take idx out of its buckets' representatives under its old
            # class/home; the transition pass re-adds it under the new
            cls_idx = self._cls(self.support[idx], self.local_support[idx])
            for i, key in enumerate(self.keys[idx]):
                self._rep_remove((i, key), idx, cls_idx, src)
            pre[idx] = (0, 0)  # re-class from scratch after the move
            self.home[idx] = dst
            self.local_support[idx] = 0  # recomputed bucket by bucket
        pred = self.core_eligible
        e_idx = True if pred is None else self.elig[idx]
        for i, key in enumerate(self.keys[idx]):
            b = (i, key)
            sc = self.shard_count[b]
            sc[src] -= 1
            before = len(sc)
            if sc[src] == 0:
                del sc[src]
            sc[dst] = sc.get(dst, 0) + 1
            after = len(sc)
            if before > 1 and after == 1:
                self.n_boundary_buckets -= 1
            elif before == 1 and after > 1:
                self.n_boundary_buckets += 1
            if not inc:
                continue
            # local crossings run on eligible per-shard counts; moving a
            # non-eligible point shifts none of them
            if pred is None:
                es = sc
            elif e_idx:
                es = self.elig_sc[b]
                es[src] -= 1
                if es[src] == 0:
                    del es[src]
                es[dst] = es.get(dst, 0) + 1
            else:
                self._refresh_interesting(b)
                continue
            # src shard lost a member: crossing k-1 demotes its residents
            if es.get(src, 0) == self.k - 1:
                for y in self.members[b]:
                    if (y != idx and self.home[y] == src
                            and (pred is None or self.elig[y])):
                        self._pre(pre, y)
                        self.local_support[y] -= 1
            # dst shard gained one: crossing k promotes its residents
            if es.get(dst, 0) == self.k:
                for y in self.members[b]:
                    if (y != idx and self.home[y] == dst
                            and (pred is None or self.elig[y])):
                        self._pre(pre, y)
                        self.local_support[y] += 1
            if es.get(dst, 0) >= self.k:
                self.local_support[idx] += 1
            self._refresh_interesting(b)
        if inc:
            self._apply_transitions(pre)
            self.epoch += 1

    def _drop_core_from(self, b: BucketKey) -> None:
        if b in self.n_cores:
            n = self.n_cores[b] - 1
            if n:
                self.n_cores[b] = n
            else:
                del self.n_cores[b]
                self._rep.pop(b, None)

    def _bucket_core(self, b: BucketKey) -> Optional[int]:
        """Some live global core of bucket ``b`` (cached; rescanned only
        after core churn invalidates the cache)."""
        mem = self.members.get(b)
        if not mem or not self.n_cores.get(b, 0):
            return None
        rep = self._rep.get(b)
        if rep is not None and rep in mem and self.support.get(rep, 0) > 0:
            self._c_rep_hit.inc()
            return rep
        self._c_rep_miss.inc()
        for m in mem:
            if self.support.get(m, 0) > 0:
                self._rep[b] = m
                return m
        return None

    def is_core(self, idx: int) -> bool:
        return self.support[idx] > 0

    # ------------------------------------------------------------------ #
    # incremental queries: inner-find -> bridge-find over the boundary
    # ------------------------------------------------------------------ #
    # hot-path
    def _quotient(self, comp_of: Callable[[int], int],
                  comp_of_batch: Optional[Callable] = None) -> Dict[int, int]:
        """Epoch-cached entry to :meth:`_quotient_build`: the common case
        (no mutation since the last query) is one dict lookup."""
        if self._q_epoch == self.epoch:
            self._c_q_hit.inc()
            return self._q_parent
        self._c_q_miss.inc()
        with self.obs.tracer.span("bridge.quotient",
                                  interesting=len(self.interesting)), \
                self._h_quotient_us.timer():
            return self._quotient_build(comp_of, comp_of_batch)

    def _quotient_build(self, comp_of: Callable[[int], int],
                        comp_of_batch: Optional[Callable] = None
                        ) -> Dict[int, int]:
        """The epoch's quotient union-find over inner component handles:
        chain every interesting bucket's merge representatives through
        their current inner components.  A handle is whatever the inner
        engine's native find returns (for the Euler-tour engines, the
        forest's canonical node payload, built from globally-unique point
        handles) — orderable and never colliding across shards, so the
        handle alone keys the node.  The representatives are maintained
        under the updates themselves, so the build does no directory
        scans — its cost is one inner ROOT per distinct representative
        (memoised across buckets).

        Three phases — gather, resolve, chain — so a remote-shard caller
        can pass ``comp_of_batch`` and resolve every representative in
        one round trip per shard instead of one per ROOT walk.  The
        result is identical either way: union is by min handle, so the
        final roots do not depend on resolution or chaining order.
        """
        keys = self.keys
        home = self.home
        # 1. gather: each chained bucket's units as resolution tasks.
        # Locally-core cores sharing one (shard, table-0 cell) are
        # provably one inner component — the home forest chains every
        # bucket it sees, and a table-0 bucket never spans shards — so
        # their task key is the cell, collapsing the root walks to one
        # per distinct cell.  Boundary cores are not locally chained and
        # resolve per point (task key ("bc", m)).
        tasks: Dict[Tuple, int] = {}  # task key -> point to resolve
        groups: List[List[Tuple]] = []
        reps_map = self._reps
        for b in self.interesting:
            ent = reps_map.get(b)
            if ent is None or ent.units() < 2:
                continue  # at most one component: nothing to chain
            g: List[Tuple] = []
            for shard, m in ent.lc_rep.items():
                if m is None:
                    m = self._lc_rep_of(b, shard)
                cell = (home[m], keys[m][0])
                tasks.setdefault(cell, m)
                g.append(cell)
            for m in ent.bc:
                bc = ("bc", m)
                tasks.setdefault(bc, m)
                g.append(bc)
            groups.append(g)
        # 2. resolve every distinct representative's inner component
        if comp_of_batch is None:
            node = {tk: comp_of(m) for tk, m in tasks.items()}
        else:
            order = list(tasks)
            vals = comp_of_batch([tasks[tk] for tk in order])
            node = dict(zip(order, vals))
        # 3. chain
        parent: Dict[int, int] = {}

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        for g in groups:
            n0: Optional[int] = None
            for tk in g:
                v = node[tk]
                parent.setdefault(v, v)
                if n0 is None:
                    n0 = v
                    continue
                ra, rb = find(n0), find(v)
                if ra != rb:
                    parent[max(ra, rb)] = min(ra, rb)
        self._q_parent = parent
        self._q_epoch = self.epoch
        self.n_quotient_builds += 1
        return parent

    def _q_find(self, node: int) -> int:  # hot-path
        parent = self._q_parent
        if node not in parent:
            return node  # component untouched by any interesting bucket
        while parent[node] != node:
            parent[node] = parent[parent[node]]
            node = parent[node]
        return node

    # hot-path
    def resolve(self, idx: int, comp_of: Callable[[int], int],
                anchored: bool,
                comp_of_batch: Optional[Callable] = None) -> Optional[int]:
        """Global component handle of live ``idx`` (None = noise) — the
        label() hot path.  ``comp_of`` is the inner engines' native find
        (Euler-tour ROOT, by global handle); ``anchored`` says whether the
        home shard holds a local anchor for a non-core ``idx``;
        ``comp_of_batch`` (optional) lets a quotient rebuild resolve its
        representatives in bulk (one round trip per remote shard)."""
        self._quotient(comp_of, comp_of_batch)
        if self.support[idx] > 0 or anchored:
            return self._q_find(comp_of(idx))
        if self.attach_orphans:
            # border point whose only colliding core is remote (or was
            # locally sub-threshold): first core bucket in table order,
            # matching LinkNonCorePoint's scan order
            for i, key in enumerate(self.keys[idx]):
                c = self._bucket_core((i, key))
                if c is not None:
                    return self._q_find(comp_of(c))
        return None

    # ------------------------------------------------------------------ #
    # the merge pass (full scan when incremental=False; labels() on the
    # incremental path restricts step 2 to the interesting buckets)
    # ------------------------------------------------------------------ #
    def merge(self, shard_labels: Iterable[Dict[int, int]],
              boundary_only: bool = False) -> Dict[int, int]:
        """Global canonical labelling from the per-shard labellings.

        Components are numbered by first occurrence in ascending-id order;
        noise (global non-core with no colliding global core) -> NOISE.
        With ``boundary_only`` step 2 chains just the maintained
        interesting-bucket set instead of scanning the whole directory —
        exact, because the local chains already cover every other bucket.
        """
        with self.obs.tracer.span("bridge.merge",
                                  boundary_only=boundary_only), \
                self._h_merge_us.timer():
            return self._merge_impl(shard_labels, boundary_only)

    def _merge_impl(self, shard_labels: Iterable[Dict[int, int]],
                    boundary_only: bool) -> Dict[int, int]:
        if boundary_only:
            self.n_boundary_merges += 1
        else:
            self.n_merge_passes += 1
        parent: Dict[int, int] = {i: i for i in self.support}

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

        # 1. shard-local components (intra-shard forests do the bulk work)
        clustered: Set[int] = set()
        for lab in shard_labels:
            rep: Dict[int, int] = {}
            for i, l in lab.items():
                if l == NOISE:
                    continue
                clustered.add(i)
                if l in rep:
                    union(rep[l], i)
                else:
                    rep[l] = i

        # 2. cross-shard core chains: any bucket the local chains could
        #    not fully cover (spans shards, or holds a core whose support
        #    is remote) gets its global cores chained here.
        buckets = (self.interesting if boundary_only else self.members)
        for b in buckets:
            mem = self.members[b]
            if len(mem) < 2:
                continue
            cores = sorted(m for m in mem if self.support[m] > 0)
            if len(cores) >= 2:
                before = {find(c) for c in cores}
                if len(before) > 1:
                    self.n_bridge_unions += len(before) - 1
                    for u, v in zip(cores, cores[1:]):
                        union(u, v)

        # 3. border points whose only colliding core is remote (or was
        #    locally sub-threshold): attach to the first global core found
        #    in table order, matching LinkNonCorePoint's scan order.
        #    Gated on attach_orphans — with re-attachment disabled the
        #    engines leave such points noise, and so do we.
        if self.attach_orphans:
            for i, sup in self.support.items():
                if sup > 0 or i in clustered:
                    continue
                for ti, key in enumerate(self.keys[i]):
                    cores = [m for m in self.members[(ti, key)]
                             if m != i and self.support[m] > 0]
                    if cores:
                        union(i, min(cores))
                        clustered.add(i)
                        break

        # canonicalise: number components by first occurrence, sorted ids
        out: Dict[int, int] = {}
        number: Dict[int, int] = {}
        for i in sorted(self.support):
            if self.support[i] == 0 and i not in clustered:
                out[i] = NOISE
            else:
                r = find(i)
                out[i] = number.setdefault(r, len(number))
        return out

    # ------------------------------------------------------------------ #
    # diagnostics
    # ------------------------------------------------------------------ #
    def check(self, home: Dict[int, int]) -> None:
        """Directory self-check against the home map (used by tests)."""
        assert set(self.keys) == set(home), "directory/home id mismatch"
        pred = self.core_eligible
        # support counts are exact w.r.t. global (eligible) bucket sizes
        for idx, keys in self.keys.items():
            if pred is None:
                s = sum(1 for i, key in enumerate(keys)
                        if len(self.members[(i, key)]) >= self.k)
            elif self.elig[idx]:
                s = sum(1 for i, key in enumerate(keys)
                        if self.n_elig.get((i, key), 0) >= self.k)
            else:
                s = 0
            assert s == self.support[idx], (idx, s, self.support[idx])
        # eligible-count structures are exact mirrors of membership
        if pred is not None:
            assert set(self.elig) == set(self.keys)
            for idx in self.keys:
                assert self.elig[idx] == bool(pred(idx)), idx
            for b, mem in self.members.items():
                ne = sum(1 for m in mem if self.elig[m])
                assert ne == self.n_elig.get(b, 0), (b, ne)
                esc: Dict[int, int] = {}
                for m in mem:
                    if self.elig[m]:
                        esc[home[m]] = esc.get(home[m], 0) + 1
                assert esc == self.elig_sc.get(b, {}), (b, esc)
        # per-shard occupancy matches the home map; boundary count exact
        n_boundary = 0
        for b, mem in self.members.items():
            assert mem, b
            sc: Dict[int, int] = {}
            for m in mem:
                sc[home[m]] = sc.get(home[m], 0) + 1
            assert sc == self.shard_count[b], (b, sc, self.shard_count[b])
            if len(sc) > 1:
                n_boundary += 1
        assert n_boundary == self.n_boundary_buckets, (
            n_boundary, self.n_boundary_buckets)
        if self.incremental:
            self._check_incremental(home)

    def _check_incremental(self, home: Dict[int, int]) -> None:
        """The maintained boundary structure is exact."""
        assert self.home == home
        pred = self.core_eligible
        for idx, keys in self.keys.items():
            if pred is None:
                loc = sum(
                    1 for i, key in enumerate(keys)
                    if self.shard_count[(i, key)].get(home[idx], 0) >= self.k)
            elif self.elig[idx]:
                loc = sum(
                    1 for i, key in enumerate(keys)
                    if self.elig_sc.get((i, key), {}).get(home[idx], 0)
                    >= self.k)
            else:
                loc = 0
            assert loc == self.local_support[idx], (
                idx, loc, self.local_support[idx])
        interesting: Set[BucketKey] = set()
        seen_reps: Set[BucketKey] = set()
        for b, mem in self.members.items():
            nc = sum(1 for m in mem if self.support[m] > 0)
            assert nc == self.n_cores.get(b, 0), (b, nc, self.n_cores.get(b))
            bc = {m for m in mem
                  if self._cls(self.support[m], self.local_support[m])
                  == _BOUNDARY_CORE}
            lc: Dict[int, int] = {}
            for m in mem:
                if (self._cls(self.support[m], self.local_support[m])
                        == _LOCAL_CORE):
                    lc[home[m]] = lc.get(home[m], 0) + 1
            ent = self._reps.get(b)
            if bc or lc:
                seen_reps.add(b)
                assert ent is not None, b
                assert ent.bc == bc, (b, ent.bc, bc)
                assert ent.lc_count == lc, (b, ent.lc_count, lc)
                for s, m in ent.lc_rep.items():
                    assert s in lc, (b, s)
                    if m is not None:  # cached rep is a valid stand-in
                        assert (home[m] == s and self.support[m] > 0
                                and self.local_support[m] > 0 and m in mem), \
                            (b, s, m)
            else:
                assert ent is None, (b, ent)
            if bc or len(self.shard_count[b]) > 1:
                interesting.add(b)
        assert set(self._reps) == seen_reps
        assert interesting == self.interesting
