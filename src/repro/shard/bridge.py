"""Boundary bridge: cross-shard cluster merging over the collision graph.

A shard's inner index only sees its own points, so two global facts are
invisible to it:

  * **support** — Definition 4 is global: a bucket with ``k`` members
    split across shards makes all of them core, while every local bucket
    stays sub-threshold;
  * **connectivity** — core points sharing a bucket are one cluster even
    when they live on different shards (and border points may have their
    only colliding core on a remote shard).

Following the merge step of theoretically-efficient parallel DBSCAN
(Wang, Gu & Shun), the bridge keeps a directory of the *global* buckets —
membership, per-shard occupancy and exact support counts (the same
threshold-crossing bookkeeping DynamicDBSCAN does, minus the forest) —
and produces the global partition as a small union pass:

  1. union each shard-local component (always a *refinement* of the
     global partition: a local core is a global core, and every local
     edge is a global collision edge);
  2. chain the global cores of every bucket that local chains could have
     missed (cross-shard buckets, or buckets containing a core whose
     support is remote);
  3. attach locally-noise non-core points to a colliding global core.

Steps 2–3 touch only boundary structure; intra-shard connectivity rides
on the inner Euler-tour forests for free.

Equivalence caveat (shared with the repo's cross-backend equivalence in
general): which cluster a *border* point joins is a tie-break.  When a
non-core point collides with cores of two different clusters, the
single-shard engine keeps whichever anchor its update history produced,
while the merge keeps the shard-local anchor (or scans tables in order
for a remote one) — the core partition and the noise set always match,
but such a border point can land in the other colliding cluster.  The
paper's well-separated workloads never exercise the tie.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from ..core.dynamic_dbscan import NOISE

BucketKey = Tuple[int, bytes]  # (table, key bytes)


class BoundaryBridge:
    def __init__(self, t: int, k: int, attach_orphans: bool = True):
        self.t, self.k = int(t), int(k)
        self.attach_orphans = attach_orphans
        self.members: Dict[BucketKey, Set[int]] = {}
        self.shard_count: Dict[BucketKey, Dict[int, int]] = {}
        self.keys: Dict[int, List[bytes]] = {}
        self.support: Dict[int, int] = {}  # #buckets of size >= k (global)
        self.n_boundary_buckets = 0  # buckets whose members span >1 shard
        self.n_merge_passes = 0
        self.n_bridge_unions = 0

    # ------------------------------------------------------------------ #
    # directory maintenance (mirrors DynamicDBSCAN's support bookkeeping)
    # ------------------------------------------------------------------ #
    def insert(self, idx: int, keys: List[bytes], shard: int) -> None:
        self.keys[idx] = keys
        self.support[idx] = 0
        for i, key in enumerate(keys):
            b = (i, key)
            mem = self.members.setdefault(b, set())
            mem.add(idx)
            sc = self.shard_count.setdefault(b, {})
            sc[shard] = sc.get(shard, 0) + 1
            if sc[shard] == 1 and len(sc) == 2:
                self.n_boundary_buckets += 1
            sz = len(mem)
            if sz == self.k:
                for y in mem:
                    self.support[y] += 1
            elif sz > self.k:
                self.support[idx] += 1

    def delete(self, idx: int, shard: int) -> None:
        for i, key in enumerate(self.keys[idx]):
            b = (i, key)
            mem = self.members[b]
            mem.discard(idx)
            sc = self.shard_count[b]
            sc[shard] -= 1
            if sc[shard] == 0:
                del sc[shard]
                if len(sc) == 1:
                    self.n_boundary_buckets -= 1
            if len(mem) == self.k - 1:
                for y in mem:
                    self.support[y] -= 1
            if not mem:
                del self.members[b]
                del self.shard_count[b]
        del self.keys[idx]
        del self.support[idx]

    def move(self, idx: int, src: int, dst: int) -> None:
        """Re-home ``idx`` (rebalance): membership and support are
        placement-invariant; only the per-shard occupancy changes."""
        if src == dst:
            return
        for i, key in enumerate(self.keys[idx]):
            sc = self.shard_count[(i, key)]
            sc[src] -= 1
            before = len(sc)
            if sc[src] == 0:
                del sc[src]
            sc[dst] = sc.get(dst, 0) + 1
            after = len(sc)
            if before > 1 and after == 1:
                self.n_boundary_buckets -= 1
            elif before == 1 and after > 1:
                self.n_boundary_buckets += 1

    def is_core(self, idx: int) -> bool:
        return self.support[idx] > 0

    # ------------------------------------------------------------------ #
    # the merge pass
    # ------------------------------------------------------------------ #
    def merge(self, shard_labels: Iterable[Dict[int, int]]) -> Dict[int, int]:
        """Global canonical labelling from the per-shard labellings.

        Components are numbered by first occurrence in ascending-id order;
        noise (global non-core with no colliding global core) -> NOISE.
        """
        self.n_merge_passes += 1
        parent: Dict[int, int] = {i: i for i in self.support}

        def find(a: int) -> int:
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        def union(a: int, b: int) -> None:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[max(ra, rb)] = min(ra, rb)

        # 1. shard-local components (intra-shard forests do the bulk work)
        clustered: Set[int] = set()
        for lab in shard_labels:
            rep: Dict[int, int] = {}
            for i, l in lab.items():
                if l == NOISE:
                    continue
                clustered.add(i)
                if l in rep:
                    union(rep[l], i)
                else:
                    rep[l] = i

        # 2. cross-shard core chains: any bucket the local chains could
        #    not fully cover (spans shards, or holds a core whose support
        #    is remote) gets its global cores chained here.
        for b, mem in self.members.items():
            if len(mem) < 2:
                continue
            cores = sorted(m for m in mem if self.support[m] > 0)
            if len(cores) >= 2:
                before = {find(c) for c in cores}
                if len(before) > 1:
                    self.n_bridge_unions += len(before) - 1
                    for u, v in zip(cores, cores[1:]):
                        union(u, v)

        # 3. border points whose only colliding core is remote (or was
        #    locally sub-threshold): attach to the first global core found
        #    in table order, matching LinkNonCorePoint's scan order.
        #    Gated on attach_orphans — with re-attachment disabled the
        #    engines leave such points noise, and so do we.
        if self.attach_orphans:
            for i, sup in self.support.items():
                if sup > 0 or i in clustered:
                    continue
                for ti, key in enumerate(self.keys[i]):
                    cores = [m for m in self.members[(ti, key)]
                             if m != i and self.support[m] > 0]
                    if cores:
                        union(i, min(cores))
                        clustered.add(i)
                        break

        # canonicalise: number components by first occurrence, sorted ids
        out: Dict[int, int] = {}
        number: Dict[int, int] = {}
        for i in sorted(self.support):
            if self.support[i] == 0 and i not in clustered:
                out[i] = NOISE
            else:
                r = find(i)
                out[i] = number.setdefault(r, len(number))
        return out

    # ------------------------------------------------------------------ #
    # diagnostics
    # ------------------------------------------------------------------ #
    def check(self, home: Dict[int, int]) -> None:
        """Directory self-check against the home map (used by tests)."""
        assert set(self.keys) == set(home), "directory/home id mismatch"
        # support counts are exact w.r.t. global bucket sizes
        for idx, keys in self.keys.items():
            s = sum(1 for i, key in enumerate(keys)
                    if len(self.members[(i, key)]) >= self.k)
            assert s == self.support[idx], (idx, s, self.support[idx])
        # per-shard occupancy matches the home map; boundary count exact
        n_boundary = 0
        for b, mem in self.members.items():
            assert mem, b
            sc: Dict[int, int] = {}
            for m in mem:
                sc[home[m]] = sc.get(home[m], 0) + 1
            assert sc == self.shard_count[b], (b, sc, self.shard_count[b])
            if len(sc) > 1:
                n_boundary += 1
        assert n_boundary == self.n_boundary_buckets, (
            n_boundary, self.n_boundary_buckets)
