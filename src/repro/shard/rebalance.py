"""Rebalance planning: pick a key range to move off a hot shard.

The router's hash spreads table-0 cells uniformly, but real streams are
not uniform over cells (clustered data concentrates mass in few cells),
so shard loads drift.  :func:`propose_rebalance` inspects live per-slot
occupancy and returns a :class:`RebalancePlan` moving a contiguous slot
run from the most- to the least-loaded shard, sized to halve the gap —
feed it to ``ShardedIndex.rebalance``.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .router import RebalancePlan


def shard_loads(index) -> np.ndarray:
    """(S,) live point count per shard (coordinator-side home map — no
    shard round trips, so it works on every transport)."""
    return np.asarray(index.shard_sizes(), dtype=np.int64)


def propose_rebalance(index, min_gap: int = 2) -> Optional[RebalancePlan]:
    """The prefix of one of the busiest shard's key ranges whose move to
    the idlest shard minimises the resulting max-min load gap, or None if
    no candidate strictly improves it (clustered streams can concentrate
    a whole cell in one slot, so a blind 'move half the gap' overshoots)."""
    loads = shard_loads(index)
    src = int(loads.argmax())
    dst = int(loads.argmin())
    gap = int(loads[src] - loads[dst])
    if src == dst or gap < min_gap:
        return None
    # per-slot occupancy of the busy shard
    _, X_s = index._shard_rows(src)
    slot_hist = index.router.slot_loads(index.router.slots_batch(X_s))
    others = np.delete(loads, [src, dst])
    o_max = int(others.max()) if others.size else 0
    o_min = int(others.min()) if others.size else np.iinfo(np.int64).max
    best_gap, best = gap, None
    for start, stop, shard in index.router.ranges():
        if shard != src:
            continue
        moved = np.cumsum(slot_hist[start:stop])  # prefix [start, start+j+1)
        hi = np.maximum(np.maximum(loads[src] - moved, loads[dst] + moved),
                        o_max)
        lo = np.minimum(np.minimum(loads[src] - moved, loads[dst] + moved),
                        o_min)
        new_gap = hi - lo
        j = int(new_gap.argmin())
        if int(new_gap[j]) < best_gap:
            best_gap = int(new_gap[j])
            best = RebalancePlan(start, start + j + 1, dst)
    return best
