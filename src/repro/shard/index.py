"""``ShardedIndex`` — a ClusterIndex of ClusterIndexes.

Points are routed by :class:`ShardRouter` (hash of the table-0 key into
contiguous key ranges) to one of ``cfg.shards`` inner indices, each any
registered grid-bucket backend (``cfg.inner_backend``: ``dynamic``,
``batched``, ``batched-device``, ``emz-static``).  *All* shard access
goes through the wire protocol's :class:`~repro.service.ShardClient` —
``cfg.transport`` selects how a shard is reached:

  * ``"local"`` (default): the inner index lives in-process behind a
    zero-copy client — the pre-protocol behavior and performance;
  * ``"process"``: each shard is a spawned server process
    (``repro.service.worker``) reached over a socket; the coordinator
    routes on a table-0-only hash pass and the shards run the full
    t-table hash *and* the pure-Python forest updates in their own
    interpreters — true ~S× GIL-free update parallelism.  Insert
    responses piggyback the bucket-key digest that feeds the
    coordinator's bridge directory.
  * ``"tcp"``: same protocol over a reconnectable stream socket, with
    timeouts, retries and auth (see
    :class:`~repro.service.transport.TcpTransport`).

With ``cfg.replicas = R > 0`` each shard client is a fault-tolerant
*lane* (:class:`~repro.service.replica.ReplicatedClient`): one primary
plus R replicas kept bit-identical by deterministic update replay.  A
dead primary is promoted away transparently (``failover.*`` counters);
a dead lane member is respawned and resynced in the background.  With
``replicas = 0`` a dead shard surfaces as
:class:`~repro.service.transport.ShardUnavailableError`; the mutation
paths reconcile partial fan-out failure first (insert rolls back the
sub-batches that landed, delete applies bridge updates for exactly the
shards that succeeded), so coordinator state never drifts from shard
state.

Mutations fan out per-shard — ``insert_batch`` splits a run into
per-shard sub-batches, so device backends keep their one-kernel-per-run
hashing, and the sub-batches run concurrently on a thread pool
(``cfg.workers > 1``, or always for ``transport="process"`` where the
threads merely block on sockets; each shard is only ever touched by one
worker at a time; the :class:`BoundaryBridge` is the single shared
structure, lives on the coordinator, and is updated by the coordinating
thread).  The bridge reconciles cross-shard structure so ``labels()`` is
the same global partition the single-shard inner backend computes (same
cores and noise set; border-point ties — see bridge.py — may resolve to
a different colliding cluster) — bit-identical across transports.

Query hot path: with ``cfg.incremental_merge`` (default) the bridge
maintains its cross-shard union-find *under* the updates, so ``label()``
resolves as inner-find -> bridge-find — no global relabel, no O(n) merge
after a mutation.  ``incremental_merge=False`` restores the PR-2
rebuild-per-query path (and is the only option for inner engines without
``native_component_queries``, e.g. ``emz-static``).

``snapshot()`` nests the per-shard snapshots (flattened under
``shard<i>/`` keys, so it round-trips through
``CheckpointManager.save_index`` unchanged), and :meth:`rebalance`
live-migrates a key range between shards by replaying the affected rows
of the source shard's snapshot into the target — snapshot-based live
migration in miniature.

Not supported as inner backends: ``naive`` (its ε-ball components are not
collision-graph components, so shard-local merges would over-connect) and
``emz-fixed`` (insert-only).
"""

from __future__ import annotations

import contextvars
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from ..api.backends import MIXED_KEY_BACKENDS
from ..api.config import ClusterConfig
from ..api.index import ClusterIndex
from ..core.dynamic_dbscan import NOISE, check_unique_ids
from ..core.hashing import GridLSH
from ..obs import merge_snapshots, write_chrome
from ..service.replica import connect_lanes
from ..service.transport import (ShardClient, ShardUnavailableError,
                                 connect_shards)
from .bridge import BoundaryBridge
from .router import RebalancePlan, ShardRouter

UNSUPPORTED_INNER = ("naive", "emz-fixed", "sharded", "tiered")

PlanLike = Union[RebalancePlan, Tuple[int, int, int]]


class ShardedIndex(ClusterIndex):
    def __init__(self, cfg: ClusterConfig):
        super().__init__(cfg)
        if cfg.inner_backend in UNSUPPORTED_INNER:
            raise ValueError(
                f"inner_backend {cfg.inner_backend!r} cannot be sharded: "
                "cross-shard merging needs a grid-bucket engine with "
                "deletions (dynamic, batched, batched-device, emz-static)"
            )
        # inner indices are always "local" from their own point of view —
        # a worker process serves a plain in-process engine
        self._inner_cfg = cfg.replace(backend=cfg.inner_backend,
                                      transport="local")
        # "remote" = the shard is behind a wire codec (process or tcp):
        # route on table 0 only and let the shards hash in parallel
        self._remote = cfg.transport != "local"
        self.obs.set_proc("coordinator")
        if cfg.replicas > 0:
            # fault-tolerant lanes: each client is 1 primary + R replicas
            # behind the same ShardClient surface, with promotion and
            # background respawn+resync on member death
            self.clients: List[ShardClient] = connect_lanes(
                self._inner_cfg, cfg.shards, cfg.transport, cfg.replicas,
                obs=self.obs)
        else:
            self.clients = connect_shards(
                self._inner_cfg, cfg.shards, cfg.transport, obs=self.obs)
        try:
            self._init_rest(cfg)
        except Exception:
            for c in self.clients:
                c.close()
            raise

    def _init_rest(self, cfg: ClusterConfig) -> None:
        # one LSH family shared by router + bridge; identical to the inner
        # engines' (seeded from the same config), so directory keys match
        # inner bucket keys bit-for-bit
        self.lsh = GridLSH(cfg.d, cfg.eps, cfg.t, seed=cfg.seed)
        self._mixed_keys = cfg.inner_backend in MIXED_KEY_BACKENDS
        # mixed-key inners: the router slots by the same device-hash pass
        # that produces the bucket keys, so routing costs no extra pass
        self.router = ShardRouter(self.lsh, cfg.shards, seed=cfg.seed,
                                  mixed=self._mixed_keys)
        # the incremental merge resolves border points through the home
        # shard's native anchor query; recompute inners can't answer it —
        # capability discovered through the protocol handshake, so it
        # works identically for in-process and spawned shards
        self._incremental = bool(cfg.incremental_merge) and all(
            c.hello().native_component_queries for c in self.clients
        )
        self.native_component_queries = self._incremental
        # sampled inners (inner_backend="approx"): the bridge must judge
        # global support over the same deterministic id sample the inner
        # engines use, or a cross-shard bucket of non-sampled points
        # would mint cores no inner engine recognises
        core_eligible = None
        bridge_k = cfg.k
        if cfg.inner_backend == "approx" and cfg.sample_rate < 1.0:
            from ..core.approx import is_sampled
            rate, aseed = cfg.sample_rate, cfg.approx_seed
            core_eligible = lambda i: is_sampled(i, rate, aseed)  # noqa: E731
            # eligible counts are compared against the sampled analogue
            # of k — the same rescaled threshold SampledCoreDBSCAN uses
            bridge_k = max(1, int(round(cfg.k * cfg.sample_rate)))
        self.bridge = BoundaryBridge(cfg.t, bridge_k,
                                     attach_orphans=cfg.attach_orphans,
                                     incremental=self._incremental,
                                     obs=self.obs,
                                     core_eligible=core_eligible)
        # coordinator-side instruments, bound once (no-ops when cfg.obs is
        # off): per-op latency plus one RPC histogram per shard — the
        # telemetry the straggler detector and the serving report read
        self._h_insert_us = self.obs.histogram("coord.insert_batch_us")
        self._h_delete_us = self.obs.histogram("coord.delete_batch_us")
        self._h_label_us = self.obs.histogram("coord.label_us")
        self._h_labels_us = self.obs.histogram("coord.labels_us")
        self._h_rpc = [self.obs.histogram(f"rpc.shard{s}_us")
                       for s in range(cfg.shards)]
        # thread-pool fan-out: opt-in via workers for local shards; always
        # on for process shards (the threads only block on sockets, so the
        # worker processes update truly in parallel).  workers=1 forces a
        # serial fan-out on either transport.
        n_workers = 0
        if cfg.shards > 1:
            if cfg.workers and cfg.workers > 1:
                n_workers = min(int(cfg.workers), cfg.shards)
            elif self._remote and not cfg.workers:
                n_workers = cfg.shards
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=n_workers,
                               thread_name_prefix="shard")
            if n_workers else None
        )
        self._home: Dict[int, int] = {}  # idx -> shard
        self._next_idx = 0
        self._cache: Optional[Dict[int, int]] = None
        self._comp_fns: Optional[List[Callable[[int], int]]] = None

    @property
    def inners(self) -> List[ClusterIndex]:
        """The in-process inner indices (local transport only; process
        shards hold no Python reference — go through ``clients``)."""
        return [c.index for c in self.clients]  # type: ignore[attr-defined]

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        for c in self.clients:
            c.close()

    # ------------------------------------------------------------------ #
    # hashing (one vectorised pass per run, mirroring the inner key space)
    # ------------------------------------------------------------------ #
    def _route_and_key(self, X: np.ndarray) -> Tuple[np.ndarray, List[List[bytes]]]:
        """(n, d) -> ((n,) target shards, per-point bucket keys).

        One hash pass either way: the exact-key path shares a
        ``codes_batch`` pass between the router (table-0 slice) and the
        bridge directory; the mixed-key path shares the one
        ``device_keys_batch`` pass the inner engines need anyway (the
        router slots by the table-0 mixed key)."""
        t = self.cfg.t
        if self._mixed_keys:
            mixed = self.lsh.device_keys_batch(X)  # (n, t, 2) int32
            keys = [[mixed[j, i].tobytes() for i in range(t)]
                    for j in range(X.shape[0])]
            slots = self.router.slots_from_mixed(mixed[:, 0, :])
        else:
            codes = self.lsh.codes_batch(X)  # (n, t, d) int64
            keys = [[codes[j, i].tobytes() for i in range(t)]
                    for j in range(X.shape[0])]
            slots = self.router.slots_from_codes(codes[:, 0, :])
        return self.router.assignment[slots], keys

    def _keys_batch(self, X: np.ndarray) -> List[List[bytes]]:
        return self._route_and_key(X)[1]

    def _route_only(self, X: np.ndarray) -> np.ndarray:
        """(n, d) -> (n,) target shards from a *table-0-only* hash pass.

        The process-transport insert path: the coordinator pays one table
        of hashing to route, and the full t-table pass happens shard-side
        (in parallel, GIL-free), coming back as the response digest."""
        if self._mixed_keys:
            slots = self.router.slots_from_mixed(
                self.lsh.device_keys_batch(X, tables=1)[:, 0, :])
        else:
            slots = self.router.slots_from_codes(
                self.lsh.codes_batch(X, tables=1)[:, 0, :])
        return self.router.assignment[slots]

    @staticmethod
    def _digest_keys(digest: np.ndarray, t: int) -> List[List[bytes]]:
        """(m, t, w) response digest -> per-point bucket-key lists,
        byte-identical to the coordinator's own hash pass."""
        return [[digest[j, i].tobytes() for i in range(t)]
                for j in range(digest.shape[0])]

    # ------------------------------------------------------------------ #
    # per-shard fan-out
    # ------------------------------------------------------------------ #
    def _fanout(self, jobs: Dict[int, Callable[[], Any]],
                return_exceptions: bool = False) -> Dict[int, Any]:
        """Run one job per shard, on the worker pool when it pays off.

        Shards never share inner state, so per-shard jobs are safe to run
        concurrently; results (and the first exception) are collected in
        shard order, keeping the fan-out deterministic.  With
        ``return_exceptions`` a failing job's exception is *returned* in
        its shard's slot instead of raised, so mutation paths can see
        which shards applied their sub-batch and reconcile (roll back or
        apply-what-succeeded) before surfacing the first error.
        Instrumented fan-outs time each job into that shard's RPC
        histogram (the straggler signal) and submit under a copied
        contextvars context so wire spans parent under the coordinator's
        op span even from pool threads."""
        if self.obs.enabled:
            jobs = {s: self._timed_job(self._h_rpc[s], fn)
                    for s, fn in jobs.items()}
        if self._pool is None or len(jobs) <= 1:
            if not return_exceptions:
                return {s: fn() for s, fn in jobs.items()}
            out: Dict[int, Any] = {}
            for s, fn in jobs.items():
                try:
                    out[s] = fn()
                except BaseException as e:
                    out[s] = e
            return out
        if self.obs.enabled:
            futures = {s: self._pool.submit(contextvars.copy_context().run, fn)
                       for s, fn in jobs.items()}
        else:
            futures = {s: self._pool.submit(fn) for s, fn in jobs.items()}
        if not return_exceptions:
            return {s: futures[s].result() for s in sorted(futures)}
        out = {}
        for s in sorted(futures):
            try:
                out[s] = futures[s].result()
            except BaseException as e:
                out[s] = e
        return out

    @staticmethod
    def _timed_job(hist, fn: Callable[[], Any]) -> Callable[[], Any]:
        def run() -> Any:
            with hist.timer():
                return fn()
        return run

    # ------------------------------------------------------------------ #
    # mutations
    # ------------------------------------------------------------------ #
    def insert(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        return self.insert_batch(
            np.asarray(x, dtype=np.float64)[None], ids=[idx]
        )[0]

    def insert_batch(self, X: np.ndarray,
                     ids: Optional[Sequence[Optional[int]]] = None) -> List[int]:
        if not self.obs.enabled:
            return self._insert_batch_impl(X, ids)
        with self.obs.tracer.span("coord.insert_batch", n=len(X)), \
                self._h_insert_us.timer():
            return self._insert_batch_impl(X, ids)

    def _insert_batch_impl(self, X: np.ndarray,
                           ids: Optional[Sequence[Optional[int]]]) -> List[int]:
        X = np.asarray(X, dtype=np.float64)
        if X.ndim != 2 or X.shape[1] != self.cfg.d:
            raise ValueError(f"batch shape {X.shape} != (n, {self.cfg.d})")
        if ids is not None and len(ids) != X.shape[0]:
            raise ValueError("ids length must match batch size")
        n = X.shape[0]
        # resolve handles with claim_index semantics (same messages, same
        # auto-id sequence) without copying the live-id set per call
        fresh: set = set()
        out: List[int] = []
        nxt0 = nxt = self._next_idx
        for j in range(n):
            idx = None if ids is None else ids[j]
            if idx is None:
                idx = nxt
            elif idx in self._home or idx in fresh:
                raise KeyError(f"index {idx} already present")
            nxt = max(nxt, idx + 1)
            fresh.add(idx)
            out.append(idx)
        self._next_idx = nxt
        if n == 0:
            return out
        if self._remote:
            # route on table 0 only; the shards hash in parallel and the
            # insert responses piggyback the bucket-key digest the bridge
            # directory is fed from
            shards = self._route_only(X)
            keys: List[Optional[List[bytes]]] = [None] * n
        else:
            shards, keys = self._route_and_key(X)
        # fan out per shard, preserving in-shard stream order so batched
        # inners hash each sub-run in one kernel call
        jobs: Dict[int, Callable[[], Any]] = {}
        by_shard: Dict[int, np.ndarray] = {}
        for s in range(self.cfg.shards):
            rows = np.flatnonzero(shards == s)
            if rows.size:
                by_shard[s] = rows
                jobs[s] = (lambda s=s, rows=rows:
                           self.clients[s].insert_batch(
                               X[rows], ids=[out[j] for j in rows],
                               want_digest=self._remote))
        results = self._fanout(jobs, return_exceptions=True)
        failed = {s: r for s, r in results.items()
                  if isinstance(r, BaseException)}
        if failed:
            self._rollback_insert(results, by_shard, out, X, nxt0)
            raise failed[min(failed)]
        if self._remote:
            for s, rows in by_shard.items():
                sub = self._digest_keys(results[s][1], self.cfg.t)
                for pos, j in enumerate(rows):
                    keys[j] = sub[pos]
        with self.obs.tracer.span("bridge.insert", n=n):
            for j in range(n):
                s = int(shards[j])
                self._home[out[j]] = s
                self.bridge.insert(out[j], keys[j], s)
        self._cache = None
        return out

    def _rollback_insert(self, results: Dict[int, Any],
                         by_shard: Dict[int, np.ndarray],
                         out: List[int], X: np.ndarray, nxt0: int) -> None:
        """Compensate a partially applied insert fan-out: the shards that
        did apply their sub-batch get a compensating delete and the
        handle counter rewinds, so bridge/router/home state is exactly
        what it was before the call (the bridge and home map are only
        written after a fully successful fan-out, so they need no
        undo)."""
        for s, rows in by_shard.items():
            if isinstance(results.get(s), BaseException):
                continue
            try:
                self.clients[s].delete_batch([out[j] for j in rows])
            except ShardUnavailableError:  # analysis: allow[FT001]
                # double failure: this shard died between applying its
                # sub-batch and the compensation.  Its lane already ran
                # the failover path inside delete_batch; all that is left
                # is to record that the rollback could not complete.
                self.obs.counter("failover.rollback_failures").inc()
        self._next_idx = nxt0

    def delete(self, idx: int) -> None:
        with self.obs.tracer.span("coord.delete"), \
                self._h_delete_us.timer():
            if idx not in self._home:
                raise KeyError(idx)
            s = self._home.pop(idx)
            self.clients[s].delete_batch([idx])
            self.bridge.delete(idx, s)
            self._cache = None

    def delete_batch(self, ids: Sequence[int]) -> None:
        with self.obs.tracer.span("coord.delete_batch", n=len(ids)), \
                self._h_delete_us.timer():
            self._delete_batch_impl(ids)

    def _delete_batch_impl(self, ids: Sequence[int]) -> None:
        check_unique_ids(ids)
        for i in ids:
            if i not in self._home:
                raise KeyError(i)
        by_shard: Dict[int, List[int]] = {}
        for i in ids:
            by_shard.setdefault(self._home[i], []).append(i)
        results = self._fanout({s: (lambda s=s, group=group:
                                    self.clients[s].delete_batch(group))
                                for s, group in by_shard.items()},
                               return_exceptions=True)
        failed = sorted(s for s, r in results.items()
                        if isinstance(r, BaseException))
        # reconcile what actually happened: a shard that applied its
        # sub-batch gets its bridge/home updates even when a sibling
        # failed, so coordinator state tracks shard state exactly; the
        # failed shard's points stay (its deletes never applied)
        for s, group in by_shard.items():
            if s in failed:
                continue
            for i in group:
                self.bridge.delete(i, s)
                del self._home[i]
        self._cache = None
        if failed:
            raise results[failed[0]]

    # ------------------------------------------------------------------ #
    # queries (global partition = inner partitions + bridge structure)
    # ------------------------------------------------------------------ #
    def _anchor_of(self, idx: int) -> Optional[int]:
        """Home shard's native core-anchor (inner half of the find)."""
        return self.clients[self._home[idx]].core_anchor_of(idx)

    def _comp_of(self, idx: int) -> int:  # hot-path
        """Home shard's native component handle (Euler-tour ROOT)."""
        fns = self._comp_fns
        if fns is None:  # bind once; the quotient build is call-heavy
            # (LocalTransport binds these straight to the engine)
            fns = self._comp_fns = [client.component_of
                                    for client in self.clients]
        return fns[self._home[idx]](idx)

    def _comp_of_batch(self, ids: Sequence[int]) -> List[Any]:
        """Bulk native find, fanned out per home shard — the quotient
        rebuild resolves all its representatives in one round trip per
        shard (order-preserving; same values as per-point ``_comp_of``)."""
        by_shard: Dict[int, List[int]] = {}
        pos_of: Dict[int, List[int]] = {}
        for pos, i in enumerate(ids):
            s = self._home[i]
            by_shard.setdefault(s, []).append(i)
            pos_of.setdefault(s, []).append(pos)
        res = self._fanout(
            {s: (lambda s=s, grp=grp: self.clients[s].component_of_batch(grp))
             for s, grp in by_shard.items()})
        out: List[Any] = [None] * len(ids)
        for s, positions in pos_of.items():
            for pos, v in zip(positions, res[s]):
                out[pos] = v
        return out

    @property
    def _batch_resolver(self):
        # per-point resolution is already zero-copy on the local
        # transport; only remote shards benefit from batching
        return self._comp_of_batch if self._remote else None

    def _all_labels(self) -> Dict[int, int]:
        if self._cache is None:
            labs = self._fanout(
                {s: (lambda s=s: self.clients[s].labels())
                 for s in range(self.cfg.shards)})
            self._cache = self.bridge.merge(
                (labs[s] for s in sorted(labs)),
                boundary_only=self._incremental)
        return self._cache

    def label(self, idx: int) -> int:  # hot-path
        """Point query.  On the incremental path this is the hot-path
        resolution — inner-find (Euler-tour ROOT on the home shard) ->
        bridge-find (quotient over the maintained boundary-bucket set) —
        and returns an *opaque* component handle (the protocol's
        contract); ``labels()`` stays canonical."""
        if not self.obs.enabled:  # un-instrumented: zero added work
            return self._label_impl(idx)
        with self._h_label_us.timer():
            return self._label_impl(idx)

    def _label_impl(self, idx: int) -> int:  # hot-path
        if idx not in self._home:
            raise KeyError(idx)
        if self._cache is not None:
            return self._cache[idx]
        if self._incremental:
            r = self.bridge.resolve(idx, self._comp_of,
                                    self._anchor_of(idx) is not None,
                                    comp_of_batch=self._batch_resolver)
            return NOISE if r is None else r
        return self._all_labels()[idx]

    def labels(self, ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        with self.obs.tracer.span("coord.labels"), \
                self._h_labels_us.timer():
            all_lab = self._all_labels()
            if ids is None:
                return dict(all_lab)
            return {i: all_lab[i] for i in ids}

    def component_of(self, idx: int) -> int:
        return self.label(idx)

    def core_anchor_of(self, idx: int) -> Optional[int]:
        if idx not in self._home:
            raise KeyError(idx)
        if not self._incremental:
            return super().core_anchor_of(idx)
        if self.bridge.support[idx] > 0:
            return idx
        return self._anchor_of(idx)

    def drain_deltas(self):
        """Union of the inner change feeds (per-shard local handles).

        Cross-shard component merges are not itemised per point — consult
        ``stats()['bridge_epoch']`` / re-query ``label`` for listed ids.
        Returns None when any inner engine does not track changes."""
        out = []
        for client in self.clients:
            d = client.drain_deltas()
            if d is None:
                return None
            out.extend(d)
        return out

    def is_core(self, idx: int) -> bool:
        return self.bridge.is_core(idx)

    def ids(self) -> List[int]:
        return sorted(self._home)

    def __contains__(self, idx: int) -> bool:
        return idx in self._home

    def __len__(self) -> int:
        return len(self._home)

    # ------------------------------------------------------------------ #
    # rebalancing: key-range live migration via snapshot replay
    # ------------------------------------------------------------------ #
    def shard_sizes(self) -> List[int]:
        """(S,) live point count per shard, from the coordinator's home
        map (no shard round trips)."""
        sizes = [0] * self.cfg.shards
        for s in self._home.values():
            sizes[s] += 1
        return sizes

    def _shard_rows(self, s: int) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, points) of shard ``s`` from its snapshot — every built-in
        backend's state exposes fixed-dtype ``ids``/``points`` arrays."""
        state = self.clients[s].snapshot_state()
        return (np.asarray(state["ids"], dtype=np.int64),
                np.asarray(state["points"], dtype=np.float64))

    def rebalance(self, plan: Union[PlanLike, Sequence[PlanLike]]) -> Dict[str, int]:
        """Move the key ranges in ``plan`` to their target shards,
        migrating the affected live points (snapshot out of the source,
        replay into the target, same handles).  The global partition is
        unchanged — placement never affects the bridge's directory."""
        if isinstance(plan, (RebalancePlan, tuple)):
            plan = [plan]
        plans = [p if isinstance(p, RebalancePlan) else RebalancePlan(*p)
                 for p in plan]
        moved = 0
        for p in plans:
            self.router.move_range(p)
            for s in range(self.cfg.shards):
                if s == p.target:
                    continue
                ids_s, X_s = self._shard_rows(s)
                if ids_s.size == 0:
                    continue
                slots = self.router.slots_batch(X_s)
                take = (slots >= p.start) & (slots < p.stop)
                if not take.any():
                    continue
                movers = [int(i) for i in ids_s[take]]
                self.clients[s].delete_batch(movers)
                self.clients[p.target].insert_batch(X_s[take], ids=movers)
                for i in movers:
                    self.bridge.move(i, s, p.target)
                    self._home[i] = p.target
                moved += len(movers)
        self._cache = None
        return {"moved": moved, "plans": len(plans)}

    # ------------------------------------------------------------------ #
    # persistence: nested per-shard snapshots, flat npz-safe keys
    # ------------------------------------------------------------------ #
    def _state(self) -> Dict[str, np.ndarray]:
        state: Dict[str, np.ndarray] = {
            "router": self.router.state(),
            "next_idx": np.asarray(self._next_idx, dtype=np.int64),
        }
        for s, client in enumerate(self.clients):
            for key, arr in client.snapshot_state().items():
                state[f"shard{s:03d}/{key}"] = arr
        return state

    def _load_state(self, state: Dict[str, np.ndarray]) -> None:
        self.router.load_state(state["router"])
        self._next_idx = int(state["next_idx"])
        for s, client in enumerate(self.clients):
            prefix = f"shard{s:03d}/"
            sub = {key[len(prefix):]: arr for key, arr in state.items()
                   if key.startswith(prefix)}
            client.restore(self._inner_cfg.to_dict(), sub)
            ids_s, X_s = self._shard_rows(s)
            if ids_s.size:
                keys = self._keys_batch(X_s)
                for j, i in enumerate(ids_s):
                    self._home[int(i)] = s
                    self.bridge.insert(int(i), keys[j], s)
        self._cache = None

    # ------------------------------------------------------------------ #
    # diagnostics
    # ------------------------------------------------------------------ #
    def check_health(self) -> None:
        """Probe every shard lane and run its deadline-based failover
        path (promote a dead primary, evict overdue members, kick the
        background respawn).  A serving loop calls this from its idle
        path; it is a no-op for plain single-member transports."""
        for c in self.clients:
            probe = getattr(c, "check_health", None)
            if probe is not None:
                probe()

    def check_invariants(self) -> None:
        n_live = 0
        for s, client in enumerate(self.clients):
            client.check_invariants()
            shard_ids = client.ids()
            n_live += len(shard_ids)
            for i in shard_ids:
                assert self._home.get(i) == s, (i, s, self._home.get(i))
        assert n_live == len(self._home)
        self.bridge.check(self._home)
        if self._incremental and self._home:
            # the boundary-restricted labelling and the hot-path point
            # queries agree with the full-directory merge oracle
            oracle = self.bridge.merge(c.labels() for c in self.clients)
            self.bridge.n_merge_passes -= 1  # oracle pass, not serving
            assert self.labels() == oracle
            fwd: Dict[int, int] = {}
            rev: Dict[int, int] = {}
            for i in self.ids():
                r = self.bridge.resolve(i, self._comp_of,
                                        self._anchor_of(i) is not None,
                                        comp_of_batch=self._batch_resolver)
                r = NOISE if r is None else r
                assert (r == NOISE) == (oracle[i] == NOISE), (i, r, oracle[i])
                if r != NOISE:  # handles <-> oracle labels bijectively
                    assert fwd.setdefault(r, oracle[i]) == oracle[i], i
                    assert rev.setdefault(oracle[i], r) == r, i

    # ------------------------------------------------------------------ #
    # observability (pull model: structural gauges are refreshed when a
    # snapshot is taken, so the mutation hot paths never touch them)
    # ------------------------------------------------------------------ #
    def obs_refresh(self) -> None:
        """Refresh the structural gauges from current coordinator state."""
        obs = self.obs
        if not obs.enabled:
            return
        b = self.bridge
        obs.gauge("bridge.interesting_buckets").set(len(b.interesting))
        obs.gauge("bridge.boundary_buckets").set(b.n_boundary_buckets)
        obs.gauge("bridge.directory_buckets").set(len(b.members))
        obs.gauge("bridge.epoch").set(b.epoch)
        sizes = self.shard_sizes()
        obs.gauge("router.load_skew").set(self.router.load_skew(sizes))
        for s, sz in enumerate(sizes):
            obs.gauge(f"shard{s}.points").set(sz)

    def obs_snapshot(self, drain: bool = False) -> List[Dict[str, Any]]:
        """Per-process observability snapshots: the coordinator's followed
        by each shard's (pulled through the protocol — one StatsReq round
        trip per shard, which drains the shard's span buffer, so a shard
        span appears in exactly one snapshot).  ``drain`` additionally
        clears the coordinator's own span buffer.  ``[]`` when
        un-instrumented."""
        if not self.obs.enabled:
            return []
        self.obs_refresh()
        snaps = [self.obs.drain() if drain else self.obs.snapshot()]
        for c in self.clients:
            payload = c.pull_obs()
            if payload:
                snaps.append(payload)
        return snaps

    def write_trace(self, path: Union[str, Path]) -> Path:
        """Dump every span recorded so far — coordinator, wire, and shard
        side — as one Chrome/Perfetto trace-event file."""
        merged = merge_snapshots(self.obs_snapshot())
        return write_chrome(path, merged["spans"])

    def stats(self) -> Dict[str, int]:
        sizes = self.shard_sizes()
        out: Dict[str, int] = {
            "shards": self.cfg.shards,
            "workers": self.cfg.workers,
            "replicas": self.cfg.replicas,
            "process_transport": int(self.cfg.transport == "process"),
            "tcp_transport": int(self.cfg.transport == "tcp"),
            "incremental_merge": int(self._incremental),
            "n_boundary_buckets": self.bridge.n_boundary_buckets,
            "n_interesting_buckets": len(self.bridge.interesting),
            "n_merge_passes": self.bridge.n_merge_passes,
            "n_boundary_merges": self.bridge.n_boundary_merges,
            "n_bridge_unions": self.bridge.n_bridge_unions,
            "n_quotient_builds": self.bridge.n_quotient_builds,
            "bridge_epoch": self.bridge.epoch,
            "max_shard_points": max(sizes) if sizes else 0,
            "min_shard_points": min(sizes) if sizes else 0,
            # wire counters: what the protocol cost, summed over shards
            # (zero bytes on the local transport — nothing is encoded)
            "transport_round_trips": sum(c.round_trips
                                         for c in self.clients),
            "transport_bytes_sent": sum(c.bytes_sent for c in self.clients),
            "transport_bytes_received": sum(c.bytes_received
                                            for c in self.clients),
        }
        for client in self.clients:
            for key, v in client.stats()[0].items():
                out[key] = out.get(key, 0) + v
        return out
