"""Host heartbeat registry: deadline-based failure detection.

On a real fleet each host POSTs a heartbeat (host_id, step, t) to the
coordinator (or writes to a shared KV store); the trainer driver polls
``failed()`` between steps and triggers the elastic re-mesh path when a
host misses its deadline.  The clock is injectable so tests simulate
failures deterministically.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Set


class HeartbeatRegistry:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 clock: Optional[Callable[[], float]] = None):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self._clock = clock or time.monotonic
        now = self._clock()
        self._last: Dict[int, float] = {h: now for h in range(n_hosts)}
        self._step: Dict[int, int] = {h: -1 for h in range(n_hosts)}
        self._evicted: Set[int] = set()

    def beat(self, host_id: int, step: int = -1) -> None:
        if host_id in self._evicted:
            raise KeyError(f"host {host_id} was evicted; must rejoin")
        self._last[host_id] = self._clock()
        self._step[host_id] = max(self._step[host_id], step)

    def failed(self) -> List[int]:
        now = self._clock()
        return sorted(
            h for h, t in self._last.items()
            if h not in self._evicted and now - t > self.timeout_s
        )

    def evict(self, host_id: int) -> None:
        self._evicted.add(host_id)

    def rejoin(self, host_id: int) -> None:
        self._evicted.discard(host_id)
        self._last[host_id] = self._clock()

    def alive(self) -> List[int]:
        failed = set(self.failed())
        return sorted(
            h for h in self._last
            if h not in self._evicted and h not in failed
        )

    def quorum_step(self) -> int:
        """Highest step every alive host has reached (restart point)."""
        alive = self.alive()
        if not alive:
            return -1
        return min(self._step[h] for h in alive)
