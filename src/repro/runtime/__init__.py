from .heartbeat import HeartbeatRegistry  # noqa: F401
from .straggler import StragglerDetector  # noqa: F401
from .elastic import ElasticPlan, plan_remesh  # noqa: F401
