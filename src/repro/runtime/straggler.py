"""Straggler detection: per-host EWMA step times with robust outlier test.

A host is flagged when its smoothed step time exceeds
``threshold × median(EWMA over hosts)`` for ``patience`` consecutive
steps.  The driver can then exclude the host (elastic re-mesh) or, for
data-pipeline stragglers, re-assign its shard (``reassign``).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np


class StragglerDetector:
    def __init__(self, n_hosts: int, alpha: float = 0.2,
                 threshold: float = 1.8, patience: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self._ewma: Dict[int, float] = {h: float("nan") for h in range(n_hosts)}
        self._breach: Dict[int, int] = {h: 0 for h in range(n_hosts)}

    def record(self, host_id: int, step_time_s: float) -> None:
        prev = self._ewma[host_id]
        self._ewma[host_id] = (
            step_time_s if np.isnan(prev)
            else self.alpha * step_time_s + (1 - self.alpha) * prev
        )

    def update_breaches(self) -> None:
        vals = [v for v in self._ewma.values() if not np.isnan(v)]
        if len(vals) < 2:
            return
        med = float(np.median(vals))
        for h, v in self._ewma.items():
            if not np.isnan(v) and v > self.threshold * med:
                self._breach[h] += 1
            else:
                self._breach[h] = 0

    def stragglers(self) -> List[int]:
        return sorted(h for h, b in self._breach.items() if b >= self.patience)

    def ewma(self, host_id: int) -> float:
        return self._ewma[host_id]

    def record_from_obs(self, metrics: Dict[str, dict],
                        prefix: str = "rpc.shard",
                        scale: float = 1e-6) -> List[int]:
        """Feed one observation round from serving telemetry: the
        per-shard RPC latency histograms of an ``Obs`` metrics snapshot
        (``rpc.shard<N>_us`` entries, as recorded by the sharded
        coordinator's fan-out) instead of synthetic probes.  Each shard's
        p50 (µs, scaled to seconds) becomes that host's step-time sample;
        breach counters update when at least one host was fed.  Returns
        the hosts fed this round."""
        fed: List[int] = []
        for h in self._ewma:
            m = metrics.get(f"{prefix}{h}_us")
            if m and m.get("type") == "histogram" and m.get("count"):
                self.record(h, float(m["p50"]) * scale)
                fed.append(h)
        if fed:
            self.update_breaches()
        return fed
