"""Elastic re-mesh planning: membership change -> new mesh + restore plan.

Policy (1000+-node fleets): the ``model`` (and EP) extent is fixed by the
architecture's sharding; elasticity happens on the data-parallel axes.  On
failure we keep the largest slice of surviving hosts whose chip count is a
multiple of the model extent with a power-of-two DP degree, rebuild the
mesh, reshard the latest durable checkpoint (CheckpointManager restores by
PartitionSpec, so any DP degree works), and rescale grad-accumulation to
preserve the global batch.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    hosts: Tuple[int, ...]        # surviving hosts to keep
    data_parallel: int            # new DP degree
    model_parallel: int
    grad_accum: int               # rescaled to preserve global batch
    dropped_hosts: Tuple[int, ...]


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_remesh(
    alive_hosts: List[int],
    chips_per_host: int,
    model_parallel: int,
    global_batch: int,
    microbatch: int,
) -> Optional[ElasticPlan]:
    """Choose the new (DP, accum) after a membership change.

    Returns None when no viable mesh exists (fewer chips than one model
    replica)."""
    total_chips = len(alive_hosts) * chips_per_host
    if total_chips < model_parallel:
        return None
    max_dp = total_chips // model_parallel
    dp = _pow2_floor(max_dp)
    need_hosts = dp * model_parallel // chips_per_host
    need_hosts = max(need_hosts, 1)
    keep = tuple(sorted(alive_hosts)[:need_hosts])
    dropped = tuple(sorted(set(alive_hosts) - set(keep)))
    # preserve the global batch: accum × dp × microbatch == global_batch
    denom = dp * microbatch
    accum = max(1, -(-global_batch // denom))
    return ElasticPlan(
        hosts=keep, data_parallel=dp, model_parallel=model_parallel,
        grad_accum=accum, dropped_hosts=dropped,
    )
