"""Training step: remat'd forward/backward, microbatch gradient
accumulation (lax.scan), global-norm clipping, AdamW update.

Gradient accumulation both bounds live activation memory and gives XLA a
window to overlap the per-microbatch gradient reductions with the next
microbatch's backward pass (the standard pjit compute/comm overlap).
Optional gradient compression (repro.distributed.compression) hooks in
between accumulation and the optimizer update.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.registry import ModelAPI


def _split_microbatches(batch: Dict[str, Any], accum: int):
    def resh(x):
        b = x.shape[0]
        assert b % accum == 0, (b, accum)
        return x.reshape(accum, b // accum, *x.shape[1:])
    return {k: resh(v) for k, v in batch.items()}


def make_train_step(
    model: ModelAPI,
    optimizer,
    mesh=None,
    grad_accum: Optional[int] = None,
    grad_transform: Optional[Callable] = None,
) -> Callable:
    cfg = model.cfg
    accum = grad_accum if grad_accum is not None else cfg.grad_accum

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, mesh)
        return loss, metrics

    # shard_map (MoE expert parallelism) inside a scanned accumulation loop
    # trips an XLA SPMD partitioner bug (slice-size verifier failure); MoE
    # families use an unrolled accumulation loop instead.
    unrolled_accum = False  # (XLA scan+shard_map bug no longer triggers with seq-split dispatch)

    def train_step(params, opt_state, batch):
        if accum > 1:
            mbs = _split_microbatches(batch, accum)

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            carry = (g0, jnp.zeros((), jnp.float32))
            if unrolled_accum:
                for i in range(accum):
                    mb = {k: v[i] for k, v in mbs.items()}
                    carry, _ = mb_step(carry, mb)
                grads, loss_sum = carry
            else:
                (grads, loss_sum), _ = jax.lax.scan(mb_step, carry, mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics: Dict[str, Any] = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params
        )
        out = {"loss": loss, **opt_metrics}
        for k, v in (metrics or {}).items():
            out[k] = v
        return new_params, new_opt, out

    return train_step
