"""Metric instruments: counters, gauges, log-bucketed histograms.

Every instrument exists in two forms — a real one and a null one with the
same surface.  Code binds an instrument once (at construction, from its
:class:`~repro.obs.registry.Obs` handle) and calls ``inc`` / ``set`` /
``observe`` / ``timer`` unconditionally; with observability disabled the
bound instrument is the shared null singleton and the call is one no-op
method dispatch.  Hot paths that cannot afford even that guard on
``obs.enabled`` instead (a single attribute read).

Histograms are log₂-bucketed: ``observe(v)`` lands ``v`` in the bucket
``(2^(e-1), 2^e]`` via ``math.frexp`` — no per-observation allocation, a
fixed ~60-bucket worst case regardless of range, and percentile estimates
within a factor of √2 (exact ``min``/``max``/``sum``/``count`` are kept
alongside, and estimates are clamped to the observed range).  Latency
histograms record **microseconds** by convention (names end in ``_us``).
"""

from __future__ import annotations

import math
import time
from typing import Dict, Iterable, Optional, Tuple


class _NullTimer:
    """Reusable no-op context manager (stateless, shared)."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: object) -> bool:
        return False


NULL_TIMER = _NullTimer()


class _Timer:
    """Times a ``with`` block and records elapsed microseconds."""

    __slots__ = ("_h", "_t0")

    def __init__(self, h: "Histogram"):
        self._h = h

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: object) -> bool:
        self._h.observe((time.perf_counter() - self._t0) * 1e6)
        return False


class Counter:
    kind = "counter"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> Dict[str, object]:
        return {"type": "counter", "value": self.value}


class Gauge:
    kind = "gauge"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> Dict[str, object]:
        return {"type": "gauge", "value": self.value}


class Histogram:
    kind = "histogram"
    __slots__ = ("name", "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str):
        self.name = name
        self.buckets: Dict[int, int] = {}  # exponent e -> count, v <= 2^e
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        e = math.frexp(v)[1] if v > 0 else 0  # 2^(e-1) < v <= 2^e
        self.buckets[e] = self.buckets.get(e, 0) + 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def timer(self) -> _Timer:
        """``with h.timer():`` records the block's latency in µs."""
        return _Timer(self)

    # ------------------------------------------------------------------ #
    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]) from the log buckets,
        clamped to the exact observed [min, max]."""
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        seen = 0
        for e in sorted(self.buckets):
            seen += self.buckets[e]
            if seen >= target:
                # arithmetic midpoint of (2^(e-1), 2^e]
                mid = 1.5 * 2.0 ** (e - 1)
                return min(max(mid, self.min), self.max)
        return self.max

    def snapshot(self) -> Dict[str, object]:
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "buckets": {str(2.0 ** e): n
                        for e, n in sorted(self.buckets.items())},
        }

    def bounds(self) -> Iterable[Tuple[float, int]]:
        """(upper bound, count) pairs in ascending bound order."""
        for e in sorted(self.buckets):
            yield 2.0 ** e, self.buckets[e]


class NullCounter(Counter):
    __slots__ = ()

    def __init__(self) -> None:
        super().__init__("null")

    def inc(self, n: int = 1) -> None:
        pass


class NullGauge(Gauge):
    __slots__ = ()

    def __init__(self) -> None:
        super().__init__("null")

    def set(self, v: float) -> None:
        pass


class NullHistogram(Histogram):
    __slots__ = ()

    def __init__(self) -> None:
        super().__init__("null")

    def observe(self, v: float) -> None:
        pass

    def timer(self) -> Optional[_Timer]:  # type: ignore[override]
        return NULL_TIMER  # type: ignore[return-value]


NULL_COUNTER = NullCounter()
NULL_GAUGE = NullGauge()
NULL_HISTOGRAM = NullHistogram()
