"""Structured trace spans with cross-process parent/child links.

A :class:`Span` is one timed operation; spans nest through an ambient
current-span context (a :mod:`contextvars` variable, so fan-out threads
that run under a copied context parent correctly).  A span's identity is
``(trace_id, span_id, parent_id)`` — ids are allocated from a pid-salted
counter, so spans created in different processes never collide and one
``insert_batch`` renders as a single tree:

    coordinator op span
      └─ wire span (per shard, in the transport)
           └─ shard-side span (recorded in the worker, shipped back)

The process boundary is crossed with plain dicts: :meth:`Span.wire_ctx`
is injected into the message header by the codec, the worker's tracer
:meth:`Tracer.adopt`\\ s it so server-side spans parent under the wire
span, and the finished spans travel back as :meth:`Tracer.drain_export`
summaries that the client :meth:`Tracer.ingest`\\ s.

Buffers are bounded: past ``capacity`` finished spans are counted in
``dropped`` instead of stored, so tracing a long run degrades to a
truncated dump, never to unbounded memory.
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import os
import time
from typing import Any, Dict, Iterator, List, Optional

#: ambient current span (shared module-wide so spans parent across
#: components — e.g. a serving-engine span over a coordinator span)
_CURRENT: contextvars.ContextVar[Optional["Span"]] = contextvars.ContextVar(
    "repro_obs_current_span", default=None)

_SEQ = itertools.count(1)


def _new_id() -> int:
    """Process-unique span id: pid-salted counter (no randomness)."""
    return ((os.getpid() & 0xFFFFF) << 40) | next(_SEQ)


class Span:
    __slots__ = ("name", "trace_id", "span_id", "parent_id",
                 "ts_us", "dur_us", "proc", "attrs", "_t0")

    def __init__(self, name: str, trace_id: int, span_id: int,
                 parent_id: Optional[int], proc: str,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.proc = proc
        self.attrs: Dict[str, Any] = attrs or {}
        self.ts_us = 0.0
        self.dur_us = 0.0
        self._t0 = 0.0

    def wire_ctx(self) -> Dict[str, int]:
        """Trace context for the ``repro.service`` message header."""
        return {"t": self.trace_id, "s": self.span_id}

    def export(self) -> Dict[str, Any]:
        return {"name": self.name, "trace": self.trace_id,
                "span": self.span_id, "parent": self.parent_id,
                "ts": self.ts_us, "dur": self.dur_us, "proc": self.proc,
                "args": self.attrs}

    @classmethod
    def from_export(cls, d: Dict[str, Any]) -> "Span":
        sp = cls(d["name"], d["trace"], d["span"], d.get("parent"),
                 d.get("proc", "?"), dict(d.get("args") or {}))
        sp.ts_us = float(d["ts"])
        sp.dur_us = float(d["dur"])
        return sp


class _Remote:
    """Stand-in parent for a span adopted from another process."""

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: int, span_id: int):
        self.trace_id = trace_id
        self.span_id = span_id


class Tracer:
    enabled = True

    def __init__(self, proc: str = "main", capacity: int = 100_000):
        self.proc = proc
        self.capacity = capacity
        self.spans: List[Span] = []
        self.dropped = 0

    # ------------------------------------------------------------------ #
    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[Span]:
        """Record a span around the ``with`` block.  A span started while
        another is current becomes its child; otherwise it roots a new
        trace."""
        parent = _CURRENT.get()
        sid = _new_id()
        if parent is None:
            sp = Span(name, sid, sid, None, self.proc, attrs)
        else:
            sp = Span(name, parent.trace_id, sid, parent.span_id,
                      self.proc, attrs)
        sp.ts_us = time.time() * 1e6
        sp._t0 = time.perf_counter()
        token = _CURRENT.set(sp)
        try:
            yield sp
        finally:
            _CURRENT.reset(token)
            sp.dur_us = (time.perf_counter() - sp._t0) * 1e6
            if len(self.spans) < self.capacity:
                self.spans.append(sp)
            else:
                self.dropped += 1

    @contextlib.contextmanager
    def adopt(self, ctx: Dict[str, int]) -> Iterator[None]:
        """Parent the block's spans under a remote wire context."""
        token = _CURRENT.set(_Remote(int(ctx["t"]), int(ctx["s"])))
        try:
            yield
        finally:
            _CURRENT.reset(token)

    def context(self) -> Optional[Dict[str, int]]:
        """Wire context of the ambient current span, if any."""
        cur = _CURRENT.get()
        return None if cur is None else {"t": cur.trace_id, "s": cur.span_id}

    # ------------------------------------------------------------------ #
    def export(self) -> List[Dict[str, Any]]:
        return [sp.export() for sp in self.spans]

    def drain_export(self) -> List[Dict[str, Any]]:
        """Export and clear the buffer (the wire piggyback path)."""
        out = self.export()
        self.spans = []
        return out

    def ingest(self, summaries: List[Dict[str, Any]]) -> None:
        """Fold spans exported by another tracer (usually another
        process) into this buffer."""
        for d in summaries:
            if len(self.spans) < self.capacity:
                self.spans.append(Span.from_export(d))
            else:
                self.dropped += 1

    def clear(self) -> None:
        self.spans = []
        self.dropped = 0


class _NullCM:
    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_CM = _NullCM()


class NullTracer(Tracer):
    enabled = False

    def __init__(self) -> None:
        super().__init__("null", capacity=0)

    def span(self, name: str, **attrs: Any):  # type: ignore[override]
        return _NULL_CM

    def adopt(self, ctx: Dict[str, int]):  # type: ignore[override]
        return _NULL_CM

    def context(self) -> Optional[Dict[str, int]]:
        return None


NULL_TRACER = NullTracer()
