"""The per-component observability handle: registry + tracer in one.

An :class:`Obs` bundles a :class:`MetricsRegistry` and a
:class:`~repro.obs.trace.Tracer` under one ``enabled`` flag.  Components
(a ClusterIndex, a transport, the serving engine) each hold exactly one
``Obs``; with ``ClusterConfig.obs=False`` (the default) they hold the
shared :data:`NULL_OBS`, whose instruments are all no-ops — the
un-instrumented hot paths stay bit-identical to the pre-observability
tree, and the wire codec emits no trace header at all.

``make_obs(enabled, proc)`` is the one constructor call sites use, so
"is observability on" is decided in exactly one place per component.
"""

from __future__ import annotations

from typing import Any, Dict, Union

from .metrics import (NULL_COUNTER, NULL_GAUGE, NULL_HISTOGRAM, Counter,
                      Gauge, Histogram)
from .trace import NULL_TRACER, NullTracer, Tracer

Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    enabled = True

    def __init__(self) -> None:
        self._metrics: Dict[str, Instrument] = {}

    def _get(self, name: str, cls: type) -> Instrument:
        inst = self._metrics.get(name)
        if inst is None:
            inst = self._metrics[name] = cls(name)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)  # type: ignore[return-value]

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)  # type: ignore[return-value]

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)  # type: ignore[return-value]

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """JSON-able view of every instrument, in registration order."""
        return {name: inst.snapshot() for name, inst in self._metrics.items()}

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self):
        return iter(self._metrics.items())


class NullRegistry(MetricsRegistry):
    enabled = False

    def counter(self, name: str) -> Counter:
        return NULL_COUNTER

    def gauge(self, name: str) -> Gauge:
        return NULL_GAUGE

    def histogram(self, name: str) -> Histogram:
        return NULL_HISTOGRAM

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        return {}


NULL_REGISTRY = NullRegistry()


class Obs:
    """One component's observability: metrics + tracer, one flag."""

    enabled = True

    def __init__(self, proc: str = "main"):
        self.proc = proc
        self.metrics: MetricsRegistry = MetricsRegistry()
        self.tracer: Tracer = Tracer(proc)

    # instrument shortcuts (the call sites' one-liner binding surface)
    def counter(self, name: str) -> Counter:
        return self.metrics.counter(name)

    def gauge(self, name: str) -> Gauge:
        return self.metrics.gauge(name)

    def histogram(self, name: str) -> Histogram:
        return self.metrics.histogram(name)

    def set_proc(self, proc: str) -> None:
        """Re-label this component (e.g. a worker learning its shard id)."""
        self.proc = proc
        self.tracer.proc = proc

    # ------------------------------------------------------------------ #
    def snapshot(self) -> Dict[str, Any]:
        """Metrics + finished spans, JSON-able; spans stay buffered."""
        return {"proc": self.proc, "metrics": self.metrics.snapshot(),
                "spans": self.tracer.export(),
                "spans_dropped": self.tracer.dropped}

    def drain(self) -> Dict[str, Any]:
        """Like :meth:`snapshot` but clears the span buffer — the wire
        pull path, so a span ships at most once."""
        return {"proc": self.proc, "metrics": self.metrics.snapshot(),
                "spans": self.tracer.drain_export(),
                "spans_dropped": self.tracer.dropped}


class NullObs(Obs):
    enabled = False

    def __init__(self) -> None:
        self.proc = "null"
        self.metrics = NULL_REGISTRY
        self.tracer = NULL_TRACER

    def set_proc(self, proc: str) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {"proc": "null", "metrics": {}, "spans": [],
                "spans_dropped": 0}

    drain = snapshot


NULL_OBS = NullObs()


def make_obs(enabled: bool, proc: str = "main") -> Obs:
    """The one switch: a live Obs when ``enabled``, else the shared
    null handle (zero allocation, zero-op instruments)."""
    return Obs(proc) if enabled else NULL_OBS


# narrow the NullTracer import to what this module re-exports
__all__ = ["MetricsRegistry", "NullRegistry", "NULL_REGISTRY", "Obs",
           "NullObs", "NULL_OBS", "make_obs", "NullTracer"]
