"""``python -m repro.obs`` — render human-readable reports from dumps.

Subcommands:

  * ``report <trace.json>`` — per-op latency table (count, p50, p99,
    mean, total) computed from a Chrome trace-event dump's ``X`` events.
  * ``prom <snapshot.json>`` — Prometheus text exposition of a metrics
    snapshot file (one ``Obs.snapshot()`` dict or a list of them).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .export import load_chrome, merge_snapshots, span_stats, to_prometheus


def _fmt_us(v: float) -> str:
    if v >= 1e6:
        return f"{v / 1e6:.2f}s"
    if v >= 1e3:
        return f"{v / 1e3:.2f}ms"
    return f"{v:.0f}us"


def _report(path: str, as_json: bool) -> int:
    events = load_chrome(path)
    rows = span_stats(events)
    if as_json:
        print(json.dumps(rows, indent=1))
        return 0
    if not rows:
        print(f"{path}: no spans")
        return 1
    procs = len({e.get("pid") for e in events})
    print(f"{path}: {len(events)} spans, {len(rows)} ops, {procs} process lanes")
    hdr = f"{'op':<28} {'count':>6} {'p50':>10} {'p99':>10} {'mean':>10} {'total':>10}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['op']:<28} {r['count']:>6} {_fmt_us(r['p50_us']):>10} "
              f"{_fmt_us(r['p99_us']):>10} {_fmt_us(r['mean_us']):>10} "
              f"{_fmt_us(r['total_us']):>10}")
    return 0


def _prom(path: str) -> int:
    data = json.loads(Path(path).read_text())
    if isinstance(data, list):
        data = merge_snapshots(data)
    elif "proc" in data:  # a single un-merged Obs.snapshot()
        data = merge_snapshots([data])
    sys.stdout.write(to_prometheus(data.get("metrics") or {}))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    rep = sub.add_parser("report", help="per-op latency table from a "
                                        "Chrome trace-event dump")
    rep.add_argument("trace", help="path to trace-event JSON")
    rep.add_argument("--json", action="store_true", help="machine output")

    prom = sub.add_parser("prom", help="Prometheus text exposition of a "
                                       "metrics snapshot file")
    prom.add_argument("snapshot", help="path to Obs.snapshot() JSON")

    args = ap.parse_args(argv)
    if args.cmd == "report":
        return _report(args.trace, args.json)
    return _prom(args.snapshot)


if __name__ == "__main__":
    sys.exit(main())
