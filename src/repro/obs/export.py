"""Exporters: JSON snapshot, Prometheus text exposition, Chrome trace.

Three consumers, three formats, one source of truth (the registry /
tracer snapshots):

  * :func:`snapshot_json` — the raw JSON-able snapshot, for committing
    next to benchmark results;
  * :func:`to_prometheus` — the text exposition format a scrape endpoint
    would serve (counters as ``_total``, histograms as cumulative
    ``_bucket{le=...}`` series);
  * :func:`to_chrome` / :func:`write_chrome` — a Chrome/Perfetto
    trace-event dump (``chrome://tracing``, https://ui.perfetto.dev):
    one ``X`` (complete) event per span, one process lane per ``proc``
    label, and the span/parent ids carried in ``args`` so parentage is
    explicit, not just visual nesting.
"""

from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, Iterable, List, Mapping, Union

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


# ---------------------------------------------------------------------- #
# metrics
# ---------------------------------------------------------------------- #
def snapshot_json(snapshots: Union[Dict[str, Any], List[Dict[str, Any]]],
                  indent: int = 1) -> str:
    """Serialise one or many ``Obs.snapshot()`` dicts."""
    return json.dumps(snapshots, indent=indent, sort_keys=False)


def merge_snapshots(snaps: Iterable[Mapping[str, Any]]) -> Dict[str, Any]:
    """Fold per-process snapshots into one flat metrics dict with
    ``<proc>/``-prefixed names plus a single combined span list."""
    metrics: Dict[str, Any] = {}
    spans: List[Dict[str, Any]] = []
    dropped = 0
    for snap in snaps:
        proc = snap.get("proc", "?")
        for name, m in (snap.get("metrics") or {}).items():
            metrics[f"{proc}/{name}"] = m
        spans.extend(snap.get("spans") or [])
        dropped += int(snap.get("spans_dropped") or 0)
    return {"metrics": metrics, "spans": spans, "spans_dropped": dropped}


def to_prometheus(metrics: Mapping[str, Mapping[str, Any]]) -> str:
    """Text exposition of a metrics snapshot (``{name: instrument}``,
    the ``metrics`` half of ``Obs.snapshot()``)."""
    lines: List[str] = []
    for name, m in metrics.items():
        kind = m.get("type")
        pname = _prom_name(name)
        if kind == "counter":
            lines.append(f"# TYPE {pname}_total counter")
            lines.append(f"{pname}_total {m['value']}")
        elif kind == "gauge":
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {m['value']}")
        elif kind == "histogram":
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for bound, n in (m.get("buckets") or {}).items():
                cum += int(n)
                lines.append(f'{pname}_bucket{{le="{bound}"}} {cum}')
            lines.append(f'{pname}_bucket{{le="+Inf"}} {m["count"]}')
            lines.append(f"{pname}_sum {m['sum']}")
            lines.append(f"{pname}_count {m['count']}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------- #
# traces
# ---------------------------------------------------------------------- #
def to_chrome(spans: Iterable[Mapping[str, Any]]) -> Dict[str, Any]:
    """Chrome trace-event JSON from exported span dicts (the
    ``Span.export()`` shape).  Every distinct ``proc`` label becomes a
    named process lane; ids ride in ``args`` for machine checking."""
    pids: Dict[str, int] = {}
    events: List[Dict[str, Any]] = []
    for sp in spans:
        proc = sp.get("proc", "?")
        pid = pids.get(proc)
        if pid is None:
            pid = pids[proc] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": proc}})
        events.append({
            "ph": "X", "cat": "repro", "name": sp["name"],
            "ts": sp["ts"], "dur": sp["dur"], "pid": pid, "tid": 0,
            "args": {"trace": sp["trace"], "span": sp["span"],
                     "parent": sp.get("parent"),
                     **(sp.get("args") or {})},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(path: Union[str, Path],
                 spans: Iterable[Mapping[str, Any]]) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(to_chrome(spans), indent=1))
    return path


def load_chrome(path: Union[str, Path]) -> List[Dict[str, Any]]:
    """The ``X`` (complete) events of a Chrome trace dump."""
    data = json.loads(Path(path).read_text())
    events = data["traceEvents"] if isinstance(data, dict) else data
    return [e for e in events if e.get("ph") == "X"]


def histogram_summary(metrics: Mapping[str, Mapping[str, Any]],
                      prefix: str = "") -> Dict[str, Dict[str, float]]:
    """Compact ``{name: {count, p50, p99, mean}}`` view of every
    histogram in a metrics snapshot — the shape benchmarks embed in
    ``results/*.json`` rows."""
    out: Dict[str, Dict[str, float]] = {}
    for name, m in metrics.items():
        if m.get("type") != "histogram" or not m.get("count"):
            continue
        if prefix and not name.startswith(prefix):
            continue
        out[name] = {"count": m["count"], "p50": m["p50"], "p99": m["p99"],
                     "mean": m["sum"] / m["count"]}
    return out


def span_stats(events: Iterable[Mapping[str, Any]],
               percentile=None) -> List[Dict[str, Any]]:
    """Per-op latency table from trace events: exact p50/p99 over the
    recorded durations, grouped by span name, sorted by total time."""
    if percentile is None:
        def percentile(xs: List[float], q: float) -> float:
            xs = sorted(xs)
            if not xs:
                return 0.0
            k = (len(xs) - 1) * q / 100.0
            lo, hi = int(k), min(int(k) + 1, len(xs) - 1)
            return xs[lo] + (xs[hi] - xs[lo]) * (k - lo)
    groups: Dict[str, List[float]] = {}
    for e in events:
        groups.setdefault(e["name"], []).append(float(e["dur"]))
    rows = []
    for name, durs in groups.items():
        rows.append({
            "op": name, "count": len(durs),
            "p50_us": percentile(durs, 50), "p99_us": percentile(durs, 99),
            "mean_us": sum(durs) / len(durs), "total_us": sum(durs),
        })
    rows.sort(key=lambda r: -r["total_us"])
    return rows
