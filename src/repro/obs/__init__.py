"""repro.obs — metrics, tracing, and exporters for the sharded index.

One handle per component (:class:`Obs` = registry + tracer), a shared
no-op :data:`NULL_OBS` when ``ClusterConfig.obs`` is off, trace contexts
that ride the ``repro.service`` message header across the socketpair,
and exporters for JSON / Prometheus text / Chrome trace-event dumps.
``python -m repro.obs report <trace.json>`` renders a per-op latency
table from a dump.
"""

from .export import (histogram_summary, load_chrome, merge_snapshots,
                     snapshot_json, span_stats, to_chrome, to_prometheus,
                     write_chrome)
from .metrics import (NULL_COUNTER, NULL_GAUGE, NULL_HISTOGRAM, NULL_TIMER,
                      Counter, Gauge, Histogram)
from .registry import (NULL_OBS, NULL_REGISTRY, MetricsRegistry, NullObs,
                       NullRegistry, Obs, make_obs)
from .trace import NULL_TRACER, NullTracer, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram",
    "NULL_COUNTER", "NULL_GAUGE", "NULL_HISTOGRAM", "NULL_TIMER",
    "MetricsRegistry", "NullRegistry", "NULL_REGISTRY",
    "Obs", "NullObs", "NULL_OBS", "make_obs",
    "Span", "Tracer", "NullTracer", "NULL_TRACER",
    "snapshot_json", "merge_snapshots", "to_prometheus",
    "to_chrome", "write_chrome", "load_chrome",
    "histogram_summary", "span_stats",
]
