"""Architecture registry: ``--arch <id>`` resolves here."""

from __future__ import annotations

from importlib import import_module

from .base import ArchConfig, ShapeConfig, SHAPES  # noqa: F401

_MODULES = {
    "qwen1.5-110b": "qwen1_5_110b",
    "granite-20b": "granite_20b",
    "gemma3-27b": "gemma3_27b",
    "phi3-mini-3.8b": "phi3_mini",
    "dbrx-132b": "dbrx_132b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-780m": "mamba2_780m",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-small": "whisper_small",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[arch_id]}").CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


# (arch, shape) grid with documented skips (DESIGN.md §Arch-applicability)
LONG_CONTEXT_OK = ("gemma3-27b", "mamba2-780m", "hymba-1.5b")


def cell_supported(arch_id: str, shape_id: str) -> tuple[bool, str]:
    if shape_id == "long_500k" and arch_id not in LONG_CONTEXT_OK:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md)"
    return True, ""
