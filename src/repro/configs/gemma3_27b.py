"""gemma3-27b: dense 62L, d_model 5376, 32H GQA(kv=16), d_ff 21504,
vocab 262144 — 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    window=1024,
    local_global_pattern=(5, 1),
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    grad_accum=4,
    source="hf:google/gemma-3-1b-pt",
)
