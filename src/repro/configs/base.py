"""Architecture configuration dataclass + reduced smoke variants."""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: Optional[float] = None   # gemma3 global layers
    window: Optional[int] = None                # sliding-window size
    local_global_pattern: Optional[Tuple[int, int]] = None  # e.g. (5, 1)
    attn_chunk: int = 512                       # q-chunk for flash-style jnp path
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / hymba)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # VLM (llava)
    n_patches: int = 0
    d_vision: int = 0
    # numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # memory knobs (per-shape overrides happen in launch/dryrun.py)
    seq_shard_activations: bool = False  # Megatron-SP residual stream
    remat: bool = True
    grad_accum: int = 1
    # metadata
    source: str = ""

    # ------------------------------------------------------------------ #
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so TP sharding always divides."""
        return -(-self.vocab_size // 256) * 256

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + layers), for 6·N·D."""
        E, F, V = self.d_model, self.d_ff, self.vocab_size
        Hq, Hkv, Dh = self.n_heads, self.n_kv_heads, self.resolved_head_dim
        emb = V * E * (1 if self.tie_embeddings else 2)
        attn = E * (Hq + 2 * Hkv) * Dh + Hq * Dh * E
        mlp = 3 * E * F
        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn + mlp
        elif self.family == "moe":
            per_layer = attn + self.n_experts * 3 * E * F + E * self.n_experts
        elif self.family == "ssm":
            Di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = (
                E * (2 * Di + 2 * N + H) + (Di + 2 * N) * self.ssm_conv
                + Di * E + 2 * H + Di
            )
        elif self.family == "hybrid":
            Di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = (
                E * (2 * Di + 2 * N + H) + (Di + 2 * N) * self.ssm_conv
                + Di * E + 2 * H + Di
            )
            per_layer = attn + ssm + mlp
        elif self.family == "audio":
            # decoder layers have self+cross attention
            enc = self.n_encoder_layers * (attn + 2 * E * F + E * F)
            dec = self.n_layers * (2 * attn + 3 * E * F)
            return emb + enc + dec
        return emb + per_layer * self.n_layers

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.n_params()
        E, F = self.d_model, self.d_ff
        dense_like = self.n_params() - self.n_layers * (
            self.n_experts - self.top_k
        ) * 3 * E * F
        return dense_like

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16,
            n_patches=min(self.n_patches, 4) if self.n_patches else 0,
            d_vision=32 if self.d_vision else 0,
            window=min(self.window, 32) if self.window else None,
            attn_chunk=32,
            grad_accum=1,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
