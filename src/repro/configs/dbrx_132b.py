"""dbrx-132b: MoE 40L, d_model 6144, 48H GQA(kv=8), d_ff 10752,
vocab 100352, 16 experts top-4 (fine-grained).
[hf:databricks/dbrx-base; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    top_k=4,
    grad_accum=8,
    source="hf:databricks/dbrx-base",
)
