"""whisper-small: encoder-decoder 12L(+12L enc), d_model 768, 12H,
d_ff 3072, vocab 51865 — conv audio frontend is a STUB (input_specs
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    n_encoder_layers=12,
    is_encoder_decoder=True,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    source="arXiv:2212.04356",
)
