"""The paper's own workload: streaming dynamic-DBSCAN curation.

Not an LM architecture — hyperparameters of the clustering substrate used
by the data pipeline and by benchmarks (k=10, t=10, eps=0.75 per §5)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class DBSCANConfig:
    d: int = 20
    k: int = 10
    t: int = 10
    eps: float = 0.75
    batch_size: int = 1000
    window: int = 0  # sliding-window size for delete-after (0 = keep all)


CONFIG = DBSCANConfig()
