"""Sharded, atomic, async checkpointing with reshard-on-load.

Layout (one directory per step):
    ckpt_dir/
      step_000100/
        manifest.json        # tree structure, shapes, dtypes, mesh, specs
        shard_<host>.npz     # this host's param shards (addressable data)
      LATEST                 # atomic pointer file

Design points for 1000+ node fleets:
  * every host writes only its own addressable shards — no gather;
  * the manifest stores PartitionSpecs, so a restart on a DIFFERENT mesh
    (elastic downscale/upscale) reshards on load: each host reads the
    pieces overlapping its new shards (single-process simulation reads the
    union of shard files);
  * writes go to a temp dir + atomic rename; LATEST updates last, so a
    crash mid-write never corrupts the restore point;
  * an async writer thread moves serialisation off the training loop
    (checkpoint/restart requirement: bounded step-time jitter).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory, keep_n: int = 3, async_write: bool = True,
                 host_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self.host_id = host_id
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._async = async_write
        self._worker: Optional[threading.Thread] = None
        self._errors: list = []
        if async_write:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot to host memory now; write asynchronously."""
        flat = _flatten_with_paths(tree)
        arrays = {}
        specs = {}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key] = arr
            sh = getattr(leaf, "sharding", None)
            spec = getattr(sh, "spec", None)
            specs[key] = _spec_to_json(spec)
        payload = (step, arrays, specs, extra or {})
        if self._async:
            self._q.put(payload)
        else:
            self._write(payload)

    def wait(self):
        if self._async:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def _drain(self):
        while True:
            payload = self._q.get()
            try:
                self._write(payload)
            except Exception as e:  # pragma: no cover
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, payload):
        step, arrays, specs, extra = payload
        name = f"step_{step:08d}"
        tmp = self.dir / f".tmp_{name}_{os.getpid()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / f"shard_{self.host_id:05d}.npz", **arrays)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype),
                         "spec": specs[k]} for k, a in arrays.items()},
            "extra": extra,
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = self.dir / name
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        (self.dir / "LATEST.tmp").write_text(name)
        (self.dir / "LATEST.tmp").rename(self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(p for p in self.dir.glob("step_*") if p.is_dir())
        for p in steps[: -self.keep_n]:
            shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if not f.exists():
            return None
        return int(f.read_text().split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into ``template``'s tree structure.

        ``shardings``: optional matching tree of NamedShardings for the
        CURRENT mesh — arrays are placed with jax.device_put, which
        reshards if the mesh changed since the save (elastic restart).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint found")
        d = self.dir / f"step_{step:08d}"
        data: Dict[str, np.ndarray] = {}
        for f in sorted(d.glob("shard_*.npz")):
            with np.load(f) as z:
                for k in z.files:
                    data[k] = z[k]
        flat_t = _flatten_with_paths(template)
        shard_flat = _flatten_with_paths(shardings) if shardings is not None else None
        out = {}
        for key, leaf in flat_t.items():
            arr = data[key]
            if shard_flat is not None:
                out[key] = jax.device_put(arr, shard_flat[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        # rebuild tree
        leaves, treedef = jax.tree.flatten(template)
        keys = list(_flatten_with_paths(template).keys())
        return treedef.unflatten([out[k] for k in keys])

    def manifest(self, step: Optional[int] = None) -> Dict:
        if step is None:
            step = self.latest_step()
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text()
        )

    # ------------------------------------------------------------------ #
    # live cluster-index checkpointing (repro.api snapshots)
    # ------------------------------------------------------------------ #
    def save_index(self, step: int, index) -> None:
        """Persist a ``repro.api.ClusterIndex`` snapshot atomically.

        Layout mirrors the param checkpoints: ``index_<step>/state.npz``
        (fixed-dtype structure arrays) + ``manifest.json`` (the
        ClusterConfig), with a temp-dir rename and an ``LATEST_INDEX``
        pointer updated last — a crash mid-write never corrupts the
        restore point.
        """
        snap = index.snapshot()
        name = f"index_{step:08d}"
        tmp = self.dir / f".tmp_{name}_{os.getpid()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "state.npz", **snap["state"])
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "config": snap["config"], "time": time.time()}
        ))
        final = self.dir / name
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        (self.dir / "LATEST_INDEX.tmp").write_text(name)
        (self.dir / "LATEST_INDEX.tmp").rename(self.dir / "LATEST_INDEX")
        steps = sorted(p for p in self.dir.glob("index_*") if p.is_dir())
        for p in steps[: -self.keep_n]:
            shutil.rmtree(p, ignore_errors=True)

    def latest_index_step(self) -> Optional[int]:
        f = self.dir / "LATEST_INDEX"
        if not f.exists():
            return None
        return int(f.read_text().split("_")[1])

    def restore_index(self, step: Optional[int] = None):
        """Rebuild the live ClusterIndex saved by :meth:`save_index`."""
        from repro.api import restore_index as _restore

        if step is None:
            step = self.latest_index_step()
        if step is None:
            raise FileNotFoundError("no index checkpoint found")
        d = self.dir / f"index_{step:08d}"
        config = json.loads((d / "manifest.json").read_text())["config"]
        with np.load(d / "state.npz") as z:
            state = {k: z[k] for k in z.files}
        return _restore({"config": config, "state": state})


def _spec_to_json(spec):
    if spec is None:
        return None
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append(str(e))
    return out
