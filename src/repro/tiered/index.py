"""``TieredIndex`` — the approx-serves / exact-verifies ClusterIndex.

Construction: ``build_index(ClusterConfig(backend="tiered",
sample_rate=0.2, ...))``.  One config fans into both tiers — the front
is ``backend="approx"`` at the config's ``sample_rate``, the back is the
exact SoA engine (``sample_rate=1.0``) — so tier labels are directly
comparable (same LSH family, same k/t/eps) and snapshots nest both
states under one config.

Locking discipline (the reason there are three locks):

  * ``_mut_lock`` (outer) serialises mutators across *front apply +
    queue submit*, so the queue order is exactly the front apply order;
  * ``_lock`` (inner) guards the front tier and the point store; it is
    **released before the queue put**, so a mutator blocked on a full
    queue (backpressure) never holds the lock the verifier's divergence
    diff needs — no producer/consumer deadlock cycle;
  * ``_back_lock`` guards the back tier (verifier applies, escalated
    queries read).

``label()`` serves from the front tier; when the point's table-0 bucket
was recently diverged (see :mod:`repro.tiered.policy`) and the point has
already reached the back tier, the query escalates to the exact answer.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..api.config import ClusterConfig
from ..api.index import ClusterIndex
from ..api.registry import build_index
from ..core.hashing import GridLSH
from .policy import DivergencePolicy
from .verifier import Verifier


class TieredIndex(ClusterIndex):
    native_component_queries = True

    def __init__(self, cfg: ClusterConfig, queue_max: int = 64,
                 diff_every: int = 4, ttl_rounds: int = 3):
        super().__init__(cfg)
        self.front = build_index(cfg.replace(backend="approx", obs=False))
        self.back = build_index(cfg.replace(backend="soa", sample_rate=1.0,
                                            obs=False))
        # host-key LSH for the policy's bucket granularity only — it need
        # not match the engines' mixed keys, just be stable per point
        self.lsh = GridLSH(cfg.d, cfg.eps, cfg.t, seed=cfg.seed)
        self._pts: Dict[int, np.ndarray] = {}
        self._mut_lock = threading.Lock()
        self._lock = threading.RLock()
        self._back_lock = threading.RLock()
        self._lag_lock = threading.Lock()
        self._lag = 0  # points applied to front, not yet to back
        self.n_escalations = 0
        self.gauge_lag = self.obs.gauge("tiered.lag")
        self.gauge_depth = self.obs.gauge("tiered.queue_depth")
        self.gauge_ari = self.obs.gauge("tiered.divergence_ari")
        self.gauge_hot = self.obs.gauge("tiered.hot_buckets")
        self._c_esc = self.obs.counter("tiered.escalations")
        self.gauge_ari.set(1.0)
        self.policy = DivergencePolicy(ttl_rounds=ttl_rounds)
        self.verifier = Verifier(self, queue_max=queue_max,
                                 diff_every=diff_every)
        self._closed = False
        self.verifier.start()

    # ------------------------------------------------------------------ #
    # mutations: front synchronously, back via the verifier queue
    # ------------------------------------------------------------------ #
    def _key0(self, idx: int) -> bytes:
        return self.lsh.keys(self._pts[idx])[0]

    def _submit(self, op: Tuple, n: int) -> None:
        with self._lag_lock:
            self._lag += n
            self.gauge_lag.set(self._lag)
        self.verifier.submit(op)

    def insert(self, x: np.ndarray, idx: Optional[int] = None) -> int:
        return self.insert_batch(np.asarray(x, dtype=np.float64)[None],
                                 ids=[idx])[0]

    def insert_batch(self, X: np.ndarray,
                     ids: Optional[Sequence[Optional[int]]] = None
                     ) -> List[int]:
        X = np.asarray(X, dtype=np.float64)
        with self._mut_lock:
            with self._lock:
                out = self.front.insert_batch(X, ids=ids)
                for j, i in enumerate(out):
                    self._pts[i] = X[j]
            self._submit(("insert", X, out), len(out))
        return out

    def delete(self, idx: int) -> None:
        self.delete_batch([idx])

    def delete_batch(self, ids: Sequence[int]) -> None:
        ids = [int(i) for i in ids]
        with self._mut_lock:
            with self._lock:
                self.front.delete_batch(ids)  # raises before any removal
                for i in ids:
                    del self._pts[i]
            self._submit(("delete", ids, None), len(ids))

    def flush(self) -> None:
        """Barrier: back tier catches up and a divergence round runs."""
        self.verifier.flush()

    # ------------------------------------------------------------------ #
    # queries: front tier, escalated on recent divergence
    # ------------------------------------------------------------------ #
    def label(self, idx: int) -> int:
        with self._lock:
            if idx not in self.front:
                raise KeyError(idx)
            escalate = self.policy.hot(self._key0(idx),
                                       self.verifier.round_no)
            if not escalate:
                return self.front.label(idx)
        with self._back_lock:
            if idx in self.back:
                self.n_escalations += 1
                self._c_esc.inc()
                return self.back.label(idx)
        # not yet verified: the approx answer is all there is
        with self._lock:
            return self.front.label(idx)

    def labels(self, ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        with self._lock:
            return self.front.labels(ids)

    def exact_labels(self,
                     ids: Optional[Iterable[int]] = None) -> Dict[int, int]:
        """The back tier's labelling after a catch-up barrier."""
        self.flush()
        with self._back_lock:
            return self.back.labels(ids)

    def component_of(self, idx: int) -> int:
        with self._lock:
            return self.front.component_of(idx)

    def core_anchor_of(self, idx: int) -> Optional[int]:
        with self._lock:
            return self.front.core_anchor_of(idx)

    def is_core(self, idx: int) -> bool:
        with self._lock:
            return self.front.is_core(idx)

    def drain_deltas(self):
        with self._lock:
            return self.front.drain_deltas()

    def ids(self) -> List[int]:
        with self._lock:
            return self.front.ids()

    def __contains__(self, idx: int) -> bool:
        with self._lock:
            return idx in self.front

    def __len__(self) -> int:
        with self._lock:
            return len(self.front)

    # ------------------------------------------------------------------ #
    # persistence: both tiers nested under one snapshot (flattened with
    # prefixed keys, like the sharded index's shard<i>/ convention)
    # ------------------------------------------------------------------ #
    def _state(self) -> Dict[str, np.ndarray]:
        self.flush()
        with self._lock, self._back_lock:
            out = {f"front/{k}": v for k, v in self.front._state().items()}
            out.update(
                {f"back/{k}": v for k, v in self.back._state().items()})
            return out

    def _load_state(self, state: Dict[str, np.ndarray]) -> None:
        front = {k[len("front/"):]: v for k, v in state.items()
                 if k.startswith("front/")}
        back = {k[len("back/"):]: v for k, v in state.items()
                if k.startswith("back/")}
        with self._lock, self._back_lock:
            self.front._load_state(front)
            self.back._load_state(back)
            eng = self.front.engine
            for i, r in eng._row.items():
                self._pts[i] = np.array(eng._pts[r], dtype=np.float64)

    def snapshot(self) -> Dict[str, Any]:
        self.flush()
        return super().snapshot()

    def restore(self, snapshot: Dict[str, Any]) -> None:
        super().restore(snapshot)
        self.flush()

    # ------------------------------------------------------------------ #
    # lifecycle / diagnostics
    # ------------------------------------------------------------------ #
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.verifier.stop()
        self.front.close()
        self.back.close()

    def check_invariants(self) -> None:
        self.flush()
        with self._lock, self._back_lock:
            self.front.check_invariants()
            self.back.check_invariants()
            f, b = set(self.front.ids()), set(self.back.ids())
            assert f == b, ("tier id sets diverged after flush",
                            f ^ b)
            assert f == set(self._pts), "point store out of sync"

    def stats(self) -> Dict[str, Any]:
        with self._lag_lock:
            lag = self._lag
        return {
            "lag": lag,
            "queue_depth": self.verifier.ops.qsize(),
            "divergence_ari": self.verifier.last_ari,
            "diff_rounds": self.verifier.n_diff_rounds,
            "applied_batches": self.verifier.n_applied_batches,
            "escalations": self.n_escalations,
            "hot_buckets": len(self.policy),
            "front": self.front.stats(),
            "back": self.back.stats(),
        }
