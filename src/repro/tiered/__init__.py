"""``repro.tiered`` — tiered serving: approximate answers, exact verification.

The tier structure the heavy-read north star wants:

  * a **front** tier (``backend="approx"``, sampled cores) absorbs every
    mutation synchronously and serves ``label()``/``labels()``
    immediately — the caller pays approximate-engine update cost only;
  * a **back** tier (exact SoA engine) receives the same mutation stream
    through a bounded queue drained by a verifier thread — exact labels
    trail the stream by the queue lag instead of gating it;
  * the verifier periodically **diffs** the tiers (ARI over the common
    live set, ``core/metrics.py``) and exports ``tiered.lag``,
    ``tiered.queue_depth`` and ``tiered.divergence_ari`` gauges through
    ``repro.obs``;
  * a :class:`DivergencePolicy` remembers which buckets recently
    disagreed, and ``label()`` **escalates** queries for points in those
    buckets to the exact tier.

Register-once: ``backend="tiered"`` builds a :class:`TieredIndex` from
one ``ClusterConfig`` (``sample_rate`` configures the front tier), so
serving, checkpoints, and benchmarks construct it like any other
backend.
"""

from .index import TieredIndex
from .policy import DivergencePolicy
from .verifier import Verifier

__all__ = ["TieredIndex", "DivergencePolicy", "Verifier"]
