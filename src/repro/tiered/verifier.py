"""The verifier thread: async exact application + tier divergence diffs.

One daemon thread per :class:`~repro.tiered.TieredIndex`.  It drains the
bounded mutation queue, applies each batch to the exact back tier under
the back lock, and every ``diff_every`` applied batches (and on every
``flush()`` barrier) runs a divergence round:

  1. canonical labellings of both tiers over their common live set;
  2. ARI between them -> the ``tiered.divergence_ari`` gauge;
  3. per-point disagreement: inside each front cluster the majority
     (front, back) pairing is the expected mapping — points off the
     majority are *diverged*, and their table-0 buckets are marked hot
     in the :class:`~repro.tiered.DivergencePolicy`.

The queue being bounded is the backpressure contract: when the exact
tier falls more than ``queue_max`` batches behind, mutations block
instead of growing an unbounded apply backlog.
"""

from __future__ import annotations

import queue
import threading
from typing import TYPE_CHECKING, Dict, List, Tuple

from ..core.dynamic_dbscan import NOISE
from ..core.metrics import adjusted_rand_index

if TYPE_CHECKING:  # pragma: no cover
    from .index import TieredIndex

#: queue items: ("insert", X, ids) | ("delete", ids, None) |
#: ("sync", Event, None) — the barrier flush() waits on
_SYNC = "sync"


class Verifier(threading.Thread):
    def __init__(self, index: "TieredIndex", queue_max: int = 64,
                 diff_every: int = 4):
        super().__init__(name="tiered-verifier", daemon=True)
        if queue_max < 1:
            raise ValueError(f"queue_max must be >= 1, got {queue_max}")
        self.index = index
        self.ops: "queue.Queue" = queue.Queue(maxsize=queue_max)
        self.diff_every = max(1, int(diff_every))
        self.round_no = 0
        self.n_applied_batches = 0
        self.n_diff_rounds = 0
        self.last_ari = 1.0
        self._since_diff = 0
        self._stopping = threading.Event()
        self._crash: List[BaseException] = []

    # ------------------------------------------------------------------ #
    # producer side (called by TieredIndex under its mutation lock)
    # ------------------------------------------------------------------ #
    def submit(self, op: Tuple) -> None:
        while True:
            self._reraise()
            try:
                self.ops.put(op, timeout=1.0)
                return
            except queue.Full:
                if not self.is_alive():
                    raise RuntimeError(
                        "tiered verifier is not running") from None

    def flush(self) -> None:
        """Barrier: every op submitted before this call is applied to the
        back tier, and a divergence round has run on the drained state."""
        done = threading.Event()
        self.submit((_SYNC, done, None))
        while not done.wait(timeout=1.0):
            self._reraise()
            if not self.is_alive():
                raise RuntimeError("tiered verifier is not running")
        self._reraise()

    def stop(self) -> None:
        self._stopping.set()
        try:  # wake a drain blocked on an empty queue; a full queue means
            # the thread is mid-apply and will see the stop flag itself
            self.ops.put_nowait((_SYNC, threading.Event(), None))
        except queue.Full:
            pass
        self.join(timeout=30.0)

    def _reraise(self) -> None:
        if self._crash:
            raise RuntimeError("tiered verifier died") from self._crash[0]

    # ------------------------------------------------------------------ #
    # consumer side
    # ------------------------------------------------------------------ #
    def run(self) -> None:  # pragma: no branch
        idx = self.index
        while not self._stopping.is_set():
            op = self.ops.get()
            try:
                kind = op[0]
                if kind == _SYNC:
                    # drain everything already queued, then diff, so the
                    # barrier leaves the tiers comparable
                    self._drain_ready()
                    if not self._stopping.is_set():
                        self._diff()
                    op[1].set()
                else:
                    self._apply(op)
                    self._since_diff += 1
                    if self._since_diff >= self.diff_every:
                        self._diff()
            except BaseException as exc:  # noqa: BLE001 — surfaced to callers
                self._crash.append(exc)
                if kind == _SYNC:
                    op[1].set()
                return
            finally:
                idx.gauge_depth.set(self.ops.qsize())

    def _drain_ready(self) -> None:
        while True:
            try:
                op = self.ops.get_nowait()
            except queue.Empty:
                return
            if op[0] == _SYNC:
                op[1].set()
                continue
            self._apply(op)

    def _apply(self, op: Tuple) -> None:
        idx = self.index
        kind, payload, ids = op
        with idx._back_lock:
            if kind == "insert":
                idx.back.insert_batch(payload, ids=ids)
            elif kind == "delete":
                idx.back.delete_batch(payload)
            else:  # pragma: no cover - queue discipline
                raise ValueError(f"unknown tiered op {kind!r}")
        n = len(ids) if kind == "insert" else len(payload)
        with idx._lag_lock:
            idx._lag -= n
            idx.gauge_lag.set(idx._lag)
        self.n_applied_batches += 1

    # ------------------------------------------------------------------ #
    def _diff(self) -> None:
        idx = self.index
        self.round_no += 1
        self.n_diff_rounds += 1
        self._since_diff = 0
        with idx._lock:
            front = idx.front.labels()
        with idx._back_lock:
            back = idx.back.labels()
        common = sorted(set(front) & set(back))
        if not common:
            ari = 1.0
        else:
            ari = adjusted_rand_index([front[i] for i in common],
                                      [back[i] for i in common])
        self.last_ari = ari
        idx.gauge_ari.set(ari)
        idx.policy.sweep(self.round_no)
        if ari >= 1.0 or not common:
            return
        # majority (front -> back) pairing per front cluster; off-majority
        # points are the diverged set
        votes: Dict[int, Dict[int, int]] = {}
        for i in common:
            c = votes.setdefault(front[i], {})
            c[back[i]] = c.get(back[i], 0) + 1
        best = {fl: max(c.items(), key=lambda kv: (kv[1], kv[0]))[0]
                for fl, c in votes.items()}
        diverged = [i for i in common
                    if back[i] != best[front[i]]
                    and not (front[i] == NOISE and back[i] == NOISE)]
        if diverged:
            with idx._lock:
                keys = [idx._key0(i) for i in diverged if i in idx._pts]
            idx.policy.mark(keys, self.round_no)
            idx.gauge_hot.set(len(idx.policy))
