"""Escalation policy: which queries deserve the exact tier.

The verifier tells the policy which table-0 buckets held diverged
points; the policy remembers them for ``ttl_rounds`` verification
rounds.  A query escalates when its point's bucket is currently hot —
the population most likely to be mislabelled by the sampled tier is
exactly the one that disagreed recently, and bucket granularity makes
the hot set a few keys instead of a per-point ledger.
"""

from __future__ import annotations

from typing import Dict, Iterable


class DivergencePolicy:
    def __init__(self, ttl_rounds: int = 3):
        if ttl_rounds < 1:
            raise ValueError(f"ttl_rounds must be >= 1, got {ttl_rounds}")
        self.ttl_rounds = int(ttl_rounds)
        self._hot: Dict[bytes, int] = {}  # table-0 key -> expiry round
        self.n_marked = 0

    def mark(self, keys: Iterable[bytes], round_no: int) -> None:
        """Remember ``keys`` as diverged as of verification ``round_no``."""
        for key in keys:
            self._hot[key] = round_no + self.ttl_rounds
            self.n_marked += 1

    def hot(self, key: bytes, round_no: int) -> bool:
        """Should a query for a point in this bucket escalate?"""
        exp = self._hot.get(key)
        if exp is None:
            return False
        if round_no > exp:
            del self._hot[key]
            return False
        return True

    def sweep(self, round_no: int) -> None:
        """Drop expired entries (called by the verifier per round)."""
        dead = [k for k, exp in self._hot.items() if round_no > exp]
        for k in dead:
            del self._hot[k]

    def __len__(self) -> int:
        return len(self._hot)
