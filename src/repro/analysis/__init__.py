"""repro.analysis — project-specific static analysis, wired into CI.

    PYTHONPATH=src python -m repro.analysis [--json] [--select PASSES]

Six passes guard the invariants the repo otherwise enforces only by
convention (see each module's docstring for the rule tables):

  * ``protocol-exhaustiveness`` — every ``repro.service`` message is
    codec-registered, every ``*Req`` has a dispatch handler and a
    resolvable ``*Resp``, every numpy payload declares a fixed dtype;
  * ``hot-path-purity`` — ``repro/kernels`` stays vectorised (no Python
    loops / host syncs in device code) and ``# hot-path``-marked
    functions stay free of per-element numpy work;
  * ``concurrency-guards`` — fan-out callables never mutate
    coordinator-owned state (bridge/router/home map), and transport
    error paths chain their raises;
  * ``fault-tolerance-guards`` — every ``except ShardUnavailableError``
    in ``service/``/``shard/`` re-raises or routes to the failover path
    (a dead shard must surface or be failed over, never swallowed);
  * ``registry-conformance`` — every registered backend implements the
    full ClusterIndex protocol with paired snapshot/restore and a
    truthful ``native_component_queries`` capability flag;
  * ``obs-discipline`` — span/timer instruments in ``service/`` and
    ``shard/`` are opened as context managers, so a span can't leak
    open on an exception path.

Suppress one finding with ``# analysis: allow[RULE]`` on (or directly
above) the offending line; mark a serving hot path for checking with a
``# hot-path`` comment on its ``def``.  New passes subclass
:class:`~repro.analysis.base.AnalysisPass` and register with
``@register_pass`` — the CLI and tests pick them up by name.
"""

from .base import PASSES, AnalysisPass, all_passes, register_pass  # noqa: F401
from .cli import main, run_passes  # noqa: F401
from .findings import Finding  # noqa: F401
from .walker import Project, SourceFile  # noqa: F401
