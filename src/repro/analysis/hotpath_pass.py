"""hot-path purity — no Python loops or host syncs where speed lives.

Two strictness tiers, matching how the repo splits its hot code:

**Device scope** — every function in ``repro/kernels`` (the package is
device code by policy: Pallas kernel bodies, their jitted wrappers, and
the jnp oracles — the ROADMAP's roofline work depends on these staying
vectorised).  Flagged:

  HOT001  Python ``for``/``while`` (unrolls under trace; on-device work
          must be expressed as array ops or kernel grids)
  HOT002  host syncs: ``.item()``, ``float(x)``/``int(x)`` on non-literal
          values (each one stalls the device pipeline)
  HOT003  host-numpy calls (``np.*``) on traced values

**Interpreted hot scope** — any function carrying a ``# hot-path``
pragma (bridge resolution, the sharded ``label`` query, transport fast
paths).  Python loops are the idiom there, so only per-element
regressions are flagged:

  HOT101  numpy array construction inside a loop (``np.asarray`` & co.
          per element — the exact anti-pattern the vectorised batch
          paths exist to avoid)
  HOT102  ``.item()`` anywhere in the function
  HOT103  non-empty dict/list/set literal or comprehension allocated
          inside a loop (per-element container churn)

Suppress a deliberate exception with ``# analysis: allow[HOT101]`` on
the offending line.
"""

from __future__ import annotations

import ast
from typing import List

from .base import AnalysisPass, register_pass
from .findings import Finding
from .walker import Project, SourceFile, enclosing

#: numpy constructors that materialise a fresh array on the host
_NP_ALLOC = ("asarray", "array", "ascontiguousarray", "stack", "fromiter",
             "frombuffer", "concatenate", "zeros", "ones", "empty", "full")


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _np_call(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
            and f.value.id in ("np", "numpy") and f.attr in _NP_ALLOC)


def _in_loop(node: ast.AST, fn: ast.FunctionDef) -> bool:
    loop = enclosing(node, ast.For, ast.While)
    return loop is not None and enclosing(loop, ast.FunctionDef) is fn


@register_pass
class HotPathPurity(AnalysisPass):
    name = "hot-path-purity"
    description = ("kernels stay vectorised; # hot-path functions stay "
                   "free of per-element numpy work")

    def __init__(self, device_prefix: str = "kernels/"):
        super().__init__()
        self._device_prefix = device_prefix

    def run(self, project: Project) -> List[Finding]:
        for sf in project.sources():
            device_file = sf.rel.startswith(self._device_prefix)
            for fn in sf.functions():
                if device_file:
                    self._check_device(sf, fn)
                elif sf.is_hot_path(fn):
                    self._check_interpreted(sf, fn)
        return self.findings

    # ------------------------------------------------------------------ #
    def _check_device(self, sf: SourceFile, fn: ast.FunctionDef) -> None:
        where = f"device function {fn.name!r}"
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.While)):
                self.emit(sf, node.lineno, "HOT001",
                          f"Python loop in {where} — express as array ops "
                          "or a kernel grid dimension")
            elif isinstance(node, ast.Call):
                name = _call_name(node)
                if name == "item":
                    self.emit(sf, node.lineno, "HOT002",
                              f".item() in {where} forces a host sync")
                elif (name in ("float", "int")
                      and isinstance(node.func, ast.Name) and node.args
                      and not isinstance(node.args[0], ast.Constant)):
                    self.emit(sf, node.lineno, "HOT002",
                              f"{name}() on a traced value in {where} "
                              "forces a host sync")
                elif _np_call(node):
                    self.emit(sf, node.lineno, "HOT003",
                              f"host-numpy call np.{node.func.attr} in "
                              f"{where} — use jnp inside device code")

    def _check_interpreted(self, sf: SourceFile, fn: ast.FunctionDef) -> None:
        where = f"hot-path function {fn.name!r}"
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if _np_call(node) and _in_loop(node, fn):
                    self.emit(sf, node.lineno, "HOT101",
                              f"per-element np.{node.func.attr} inside a "
                              f"loop in {where} — hoist to one batch pass")
                elif _call_name(node) == "item":
                    self.emit(sf, node.lineno, "HOT102",
                              f".item() in {where} forces a device sync "
                              "per element")
            elif (isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp))
                  and _in_loop(node, fn)):
                self.emit(sf, node.lineno, "HOT103",
                          f"comprehension allocated inside a loop in "
                          f"{where} — per-element container churn")
            elif isinstance(node, (ast.List, ast.Set, ast.Dict)):
                items = node.keys if isinstance(node, ast.Dict) else node.elts
                if items and _in_loop(node, fn):
                    self.emit(sf, node.lineno, "HOT103",
                              f"non-empty container literal inside a loop "
                              f"in {where} — per-element allocation")
