"""concurrency-guards — the coordinator-serial discipline, checked.

The sharded backend's whole thread-safety argument is one sentence:
*worker threads only ever touch their own shard's client; every shared
structure (BoundaryBridge, ShardRouter, the home map, caches) is mutated
serially by the coordinating thread* — that is what keeps the threaded
fan-out bit-identical to the serial path with zero locks.  Nothing
enforces it: a well-meaning PR that updates the bridge from inside a
fan-out lambda races silently and corrupts the directory only under
load.  This pass makes the discipline machine-checked:

  CONC001  mutation of coordinator-owned state (``self.bridge.insert/
           delete/move``, ``self.router.*`` mutators, any write to a
           ``self.*`` attribute) inside a callable passed to
           ``_fanout(...)`` or ``*.submit(...)``
  CONC002  bare ``except:`` in protocol/shard modules (swallows
           ``ShardUnavailableError`` and ``KeyboardInterrupt`` alike)
  CONC003  ``raise X(...)`` without ``from`` inside an ``except`` block
           in protocol/shard modules — unchained raises strip the wire
           error's cause exactly where debugging needs it

CONC001 scans any module that uses a thread pool; CONC002/CONC003 are
scoped to ``service/`` and ``shard/`` (the transport error paths).
"""

from __future__ import annotations

import ast
from typing import List

from .base import AnalysisPass, register_pass
from .findings import Finding
from .walker import Project, SourceFile, enclosing

#: methods of coordinator-owned structures that mutate them
_MUTATORS = ("insert", "delete", "move", "delete_batch", "insert_batch",
             "move_range", "load_state", "rebalance")
#: self-attributes that name coordinator-owned shared structures
_OWNED = ("bridge", "router")

_ERROR_PATH_PREFIXES = ("service/", "shard/")


def _self_attr(node: ast.expr) -> str:
    """'bridge' for ``self.bridge``; '' otherwise."""
    if (isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return ""


def _submitted_callables(call: ast.Call) -> List[ast.AST]:
    """Lambdas/defs handed to a fan-out call, including dict-literal
    values and comprehension bodies (the repo's fan-out idioms)."""
    out: List[ast.AST] = []
    todo = list(call.args) + [kw.value for kw in call.keywords]
    while todo:
        node = todo.pop()
        if isinstance(node, ast.Lambda):
            out.append(node)
        elif isinstance(node, ast.Dict):
            todo.extend(v for v in node.values if v is not None)
        elif isinstance(node, (ast.DictComp,)):
            todo.append(node.value)
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            todo.append(node.elt)
        elif isinstance(node, ast.Tuple):
            todo.extend(node.elts)
    return out


@register_pass
class ConcurrencyGuards(AnalysisPass):
    name = "concurrency-guards"
    description = ("fan-out callables never mutate coordinator state; "
                   "transport error paths chain their raises")

    def run(self, project: Project) -> List[Finding]:
        for sf in project.sources():
            if "ThreadPoolExecutor" in sf.text or "_fanout" in sf.text:
                self._check_fanout(sf)
            if sf.rel.startswith(_ERROR_PATH_PREFIXES):
                self._check_error_paths(sf)
        return self.findings

    # ------------------------------------------------------------------ #
    def _check_fanout(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            target = f.attr if isinstance(f, ast.Attribute) else ""
            if target not in ("_fanout", "submit"):
                continue
            for cb in _submitted_callables(node):
                self._check_callable(sf, cb)

    def _check_callable(self, sf: SourceFile, cb: ast.AST) -> None:
        for node in ast.walk(cb):
            # writes to any self attribute (incl. self._home[i] = ...)
            targets: List[ast.expr] = []
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
            elif isinstance(node, ast.Delete):
                targets = list(node.targets)
            for t in targets:
                base = t.value if isinstance(t, ast.Subscript) else t
                attr = _self_attr(base)
                if attr:
                    self.emit(sf, t.lineno, "CONC001",
                              f"write to coordinator state self.{attr} "
                              "inside a fan-out callable — shared "
                              "structures are coordinator-serial")
            # mutating calls on owned structures: self.bridge.insert(...)
            if isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute) and f.attr in _MUTATORS
                        and _self_attr(f.value) in _OWNED):
                    self.emit(sf, node.lineno, "CONC001",
                              f"self.{f.value.attr}.{f.attr}() inside a "
                              "fan-out callable — bridge/router mutations "
                              "must run on the coordinating thread")

    # ------------------------------------------------------------------ #
    def _check_error_paths(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                self.emit(sf, node.lineno, "CONC002",
                          "bare except in a protocol module — name the "
                          "exceptions the transport actually raises")
            for sub in ast.walk(node):
                if (isinstance(sub, ast.Raise) and sub.exc is not None
                        and sub.cause is None
                        and enclosing(sub, ast.ExceptHandler) is node):
                    if (isinstance(sub.exc, ast.Name) and node.name
                            and sub.exc.id == node.name):
                        continue  # plain re-raise of the caught exception
                    self.emit(sf, sub.lineno, "CONC003",
                              "unchained raise inside except — add "
                              "'from e' (or 'from None') so the wire "
                              "error keeps its cause")
        return None
