"""fault-tolerance-guards — failures are handled, never swallowed.

The fault-tolerance layer's contract is that
``ShardUnavailableError`` is a *decision point*, not noise: wherever the
coordinator or a transport catches one, it must either re-raise (let the
caller decide) or take the failover path (evict the member / promote a
replica / record the compensation).  A handler that silently eats the
exception turns a dead shard into quietly wrong answers — the one
failure mode a clustering service must never have.

  FT001  ``except ShardUnavailableError`` (alone or in a tuple) in
         ``service/`` or ``shard/`` whose handler neither raises nor
         calls a failover-path function

"Failover-path function" is any call whose name is one of
``_fail_member`` / ``_schedule_repair`` / ``check_health`` or contains
``promote`` / ``failover`` — the lane's eviction/promotion entry points
plus anything named for the job.  Suppress a deliberate best-effort
handler (e.g. compensation after a double failure, where the counter is
the record) with ``# analysis: allow[FT001]``.
"""

from __future__ import annotations

import ast
from typing import List

from .base import AnalysisPass, register_pass
from .findings import Finding
from .walker import Project, SourceFile, enclosing

_SCOPED_PREFIXES = ("service/", "shard/")

#: call names that constitute "taking the failover path"
_FAILOVER_CALLS = frozenset({"_fail_member", "_schedule_repair",
                             "check_health"})
_FAILOVER_SUBSTRINGS = ("promote", "failover")


def _names_shard_unavailable(node: ast.expr) -> bool:
    """True when an except clause's type expression names
    ShardUnavailableError (bare, dotted, or inside a tuple)."""
    if isinstance(node, ast.Tuple):
        return any(_names_shard_unavailable(e) for e in node.elts)
    if isinstance(node, ast.Name):
        return node.id == "ShardUnavailableError"
    if isinstance(node, ast.Attribute):
        return node.attr == "ShardUnavailableError"
    return False


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _is_failover_call(name: str) -> bool:
    return (name in _FAILOVER_CALLS
            or any(s in name for s in _FAILOVER_SUBSTRINGS))


@register_pass
class FaultToleranceGuards(AnalysisPass):
    name = "fault-tolerance-guards"
    description = ("every ShardUnavailableError handler re-raises or "
                   "takes the failover path")

    def run(self, project: Project) -> List[Finding]:
        for sf in project.sources():
            if not sf.rel.startswith(_SCOPED_PREFIXES):
                continue
            if "ShardUnavailableError" not in sf.text:
                continue
            self._check_file(sf)
        return self.findings

    def _check_file(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None or not _names_shard_unavailable(node.type):
                continue
            if self._handler_ok(node):
                continue
            self.emit(sf, node.lineno, "FT001",
                      "ShardUnavailableError caught but neither re-raised "
                      "nor routed to the failover path — a dead shard "
                      "must surface or be failed over, never swallowed")

    @staticmethod
    def _handler_ok(handler: ast.ExceptHandler) -> bool:
        """A handler passes when *its own* body (not a nested handler's)
        raises or calls into the failover machinery."""
        for sub in ast.walk(handler):
            if sub is handler:
                continue
            inner = enclosing(sub, ast.ExceptHandler)
            if inner is not handler and inner is not None:
                continue
            if isinstance(sub, ast.Raise):
                return True
            if isinstance(sub, ast.Call) and _is_failover_call(
                    _call_name(sub)):
                return True
        return False
