"""protocol-exhaustiveness — the wire protocol has no unwired message.

The ``repro.service`` protocol's correctness rests on three conventions
that nothing at runtime checks until a frame actually crosses the wire:

  * every message class is codec-registered (an unregistered class
    encodes fine locally and explodes as ``unknown message kind`` on the
    *peer* — a version-skew landmine);
  * every ``*Req`` has a dispatch handler in ``ClusterService`` and a
    resolvable response type (the dedicated ``*Resp`` when one exists);
  * every numpy payload field declares its wire dtype (``_dtypes`` /
    ``_poly_dtypes`` / ``_array_dicts``) and none of them is ``object``
    — object arrays require pickling, which the codec (rightly) refuses.

This pass verifies all three by *importing* the messages module (the
registry and dataclass fields are runtime facts) and walking the service
module's AST for the ``_dispatch`` table (handler wiring is a source
fact).  Findings anchor to the class definition lines in the messages
source so suppression pragmas work per class.

Rules:
  PROTO001  message class not codec-registered
  PROTO002  ndarray payload field with no declared wire dtype
  PROTO003  declared wire dtype is not fixed-size (object/void)
  PROTO004  *Req class with no ClusterService dispatch handler
  PROTO005  dispatch handler with no resolvable *Resp return type
  PROTO006  handler bypasses the dedicated *Resp paired with its *Req
"""

from __future__ import annotations

import ast
import dataclasses
from types import ModuleType
from typing import Dict, List, Optional, Tuple

import numpy as np

from .base import AnalysisPass, register_pass
from .findings import Finding
from .walker import Project, SourceFile

#: generic response classes a *Req may resolve to when it has no
#: dedicated ``*Resp`` (acks and opaque-value queries)
GENERIC_RESPONSES = ("OkResp", "ValueResp", "ValuesResp", "ErrorResp")


def _message_classes(mod: ModuleType) -> Dict[str, type]:
    """Concrete message dataclasses defined in ``mod`` (kind != "")."""
    out = {}
    for name, obj in vars(mod).items():
        if (isinstance(obj, type) and dataclasses.is_dataclass(obj)
                and getattr(obj, "kind", "") and obj.__module__ == mod.__name__):
            out[name] = obj
    return out


def _class_lines(sf: Optional[SourceFile]) -> Dict[str, int]:
    if sf is None:
        return {}
    return {node.name: node.lineno for node in ast.walk(sf.tree)
            if isinstance(node, ast.ClassDef)}


class _DispatchTable:
    """The ``self._dispatch = {...}`` table of a service module, plus the
    return annotation (or constructed response) of each handler."""

    def __init__(self, sf: SourceFile, class_name: str = "ClusterService"):
        self.sf = sf
        self.entries: Dict[str, Tuple[int, Optional[str]]] = {}
        cls = next((n for n in ast.walk(sf.tree)
                    if isinstance(n, ast.ClassDef) and n.name == class_name),
                   None)
        if cls is None:
            return
        methods = {n.name: n for n in cls.body
                   if isinstance(n, ast.FunctionDef)}
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
            elif isinstance(node, ast.AnnAssign):
                t = node.target
            else:
                continue
            if not (isinstance(t, ast.Attribute) and t.attr == "_dispatch"
                    and isinstance(node.value, ast.Dict)):
                continue
            for key, val in zip(node.value.keys, node.value.values):
                req = self._req_name(key)
                if req is None:
                    continue
                self.entries[req] = (key.lineno, self._resp_name(val, methods))

    @staticmethod
    def _req_name(key: Optional[ast.expr]) -> Optional[str]:
        if isinstance(key, ast.Attribute):
            return key.attr
        if isinstance(key, ast.Name):
            return key.id
        return None

    def _resp_name(self, val: ast.expr,
                   methods: Dict[str, ast.FunctionDef]) -> Optional[str]:
        """Response class a dispatch value produces: the bound method's
        return annotation, or the ``*Resp(...)`` call a lambda returns."""
        if isinstance(val, ast.Attribute):  # self._handler
            fn = methods.get(val.attr)
            if fn is not None and fn.returns is not None:
                return self._ann_name(fn.returns)
            return None
        if isinstance(val, ast.Lambda):
            for sub in ast.walk(val.body):
                if isinstance(sub, ast.Call):
                    name = self._ann_name(sub.func)
                    if name and name.endswith("Resp"):
                        return name
        return None

    @staticmethod
    def _ann_name(node: ast.expr) -> Optional[str]:
        if isinstance(node, ast.Attribute):
            return node.attr
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value.rsplit(".", 1)[-1]
        return None


@register_pass
class ProtocolExhaustiveness(AnalysisPass):
    name = "protocol-exhaustiveness"
    description = ("every wire message is codec-registered, dispatched, "
                   "and fixed-dtype")

    #: messages module + source/service-source locations, overridable so
    #: fixture tests can analyse a synthetic protocol
    def __init__(self, messages: Optional[ModuleType] = None,
                 messages_rel: str = "service/messages.py",
                 service_rel: str = "service/service.py",
                 service_class: str = "ClusterService"):
        super().__init__()
        self._messages = messages
        self._messages_rel = messages_rel
        self._service_rel = service_rel
        self._service_class = service_class

    def run(self, project: Project) -> List[Finding]:
        mod = self._messages
        if mod is None:
            from ..service import messages as mod  # type: ignore[no-redef]
        classes = _message_classes(mod)
        registered = set(getattr(mod, "MESSAGE_TYPES", {}).values())
        msf = project.source(self._messages_rel)
        lines = _class_lines(msf)
        ssf = project.source(self._service_rel)
        table = _DispatchTable(ssf, self._service_class) if ssf else None

        for name, cls in sorted(classes.items()):
            line = lines.get(name, 0)
            if cls not in registered:
                self.emit(msf, line, "PROTO001",
                          f"message class {name} (kind={cls.kind!r}) is not "
                          "codec-registered — a peer cannot decode it")
            self._check_dtypes(msf, line, name, cls)
            if name.endswith("Req") and table is not None:
                self._check_dispatch(msf, line, name, classes, table)
        return self.findings

    # ------------------------------------------------------------------ #
    def _check_dtypes(self, msf: Optional[SourceFile], line: int,
                      name: str, cls: type) -> None:
        dtypes = getattr(cls, "_dtypes", {})
        poly = getattr(cls, "_poly_dtypes", {})
        array_dicts = getattr(cls, "_array_dicts", ())
        declared = set(dtypes) | set(poly) | set(array_dicts)
        for f in dataclasses.fields(cls):
            if "ndarray" not in str(f.type):
                continue
            if f.name not in declared:
                self.emit(msf, line, "PROTO002",
                          f"{name}.{f.name} is a numpy payload with no "
                          "declared wire dtype (_dtypes/_poly_dtypes/"
                          "_array_dicts)")
        flat = list(dtypes.items())
        flat += [(k, d) for k, ds in poly.items() for d in ds]
        for field_name, dt in flat:
            kind = np.dtype(dt).kind
            if kind in ("O", "V"):
                self.emit(msf, line, "PROTO003",
                          f"{name}.{field_name} declares non-fixed dtype "
                          f"{np.dtype(dt)!r} — object arrays cannot cross "
                          "the wire unpickled")

    def _check_dispatch(self, msf: Optional[SourceFile], line: int,
                        name: str, classes: Dict[str, type],
                        table: _DispatchTable) -> None:
        entry = table.entries.get(name)
        if entry is None:
            self.emit(msf, line, "PROTO004",
                      f"{name} has no {self._service_class}._dispatch "
                      "handler — the request is a guaranteed wire error")
            return
        dline, resp = entry
        dedicated = name[:-len("Req")] + "Resp"
        if resp is None:
            self.emit(table.sf, dline, "PROTO005",
                      f"dispatch handler for {name} has no resolvable "
                      "*Resp return type")
        elif dedicated in classes and resp != dedicated:
            self.emit(table.sf, dline, "PROTO006",
                      f"dispatch handler for {name} returns {resp}, "
                      f"bypassing its dedicated {dedicated}")
        elif dedicated not in classes and resp not in classes and \
                resp not in GENERIC_RESPONSES:
            self.emit(table.sf, dline, "PROTO005",
                      f"dispatch handler for {name} returns unknown "
                      f"response type {resp}")
