"""``python -m repro.analysis`` — run the project's static-analysis suite.

Exit codes: 0 = clean, 1 = findings, 2 = bad invocation.  ``--json``
emits a machine-readable report (one object: findings + per-pass counts)
for CI artifacts; the default output is one ``path:line: RULE message
[pass]`` line per finding, sorted by location.

The AST passes analyse the tree under ``--root`` (default: the source
tree of the importable ``repro`` package, i.e. the repo's ``src/``); the
reflection passes (protocol, registry) always introspect the *imported*
``repro`` — point PYTHONPATH and --root at the same checkout, as CI does.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .base import PASSES, all_passes
from .findings import Finding
from .walker import Project

# importing the pass modules populates the registry
from . import concurrency_pass  # noqa: F401
from . import fault_pass  # noqa: F401
from . import hotpath_pass  # noqa: F401
from . import obs_pass  # noqa: F401
from . import protocol_pass  # noqa: F401
from . import registry_pass  # noqa: F401


def run_passes(project: Project,
               names: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the named passes (default: all) and collect their findings."""
    findings: List[Finding] = []
    for name in names or all_passes():
        findings.extend(PASSES[name]().run(project))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="project-specific static analysis "
                    f"(passes: {', '.join(all_passes())})")
    ap.add_argument("--root", type=Path, default=None,
                    help="directory containing the package tree to analyse "
                         "(default: the imported repro package's parent)")
    ap.add_argument("--select", default=None, metavar="PASS[,PASS...]",
                    help="comma-separated subset of passes to run")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of text lines")
    ap.add_argument("--list", action="store_true", dest="list_passes",
                    help="list the registered passes and exit")
    args = ap.parse_args(argv)

    if args.list_passes:
        for name in all_passes():
            print(f"{name}: {PASSES[name].description}")
        return 0

    names = None
    if args.select:
        names = [n.strip() for n in args.select.split(",") if n.strip()]
        unknown = [n for n in names if n not in PASSES]
        if unknown:
            print(f"unknown pass(es): {', '.join(unknown)} "
                  f"(available: {', '.join(all_passes())})", file=sys.stderr)
            return 2

    project = Project(args.root) if args.root else Project.locate()
    findings = run_passes(project, names)

    if args.as_json:
        counts: dict = {}
        for f in findings:
            counts[f.pass_name] = counts.get(f.pass_name, 0) + 1
        print(json.dumps({"ok": not findings,
                          "n_findings": len(findings),
                          "counts": counts,
                          "findings": [f.as_dict() for f in findings]},
                         indent=2))
    else:
        for f in findings:
            print(f.render())
        print(f"{len(findings)} finding(s) from "
              f"{len(names or all_passes())} pass(es)")
    return 1 if findings else 0
