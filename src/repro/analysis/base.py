"""Analysis pass base class and registry.

A pass is a named check over a :class:`~repro.analysis.walker.Project`:
``run(project)`` returns the (unsuppressed) findings.  Passes register at
import time via :func:`register_pass`, mirroring the backend registry in
:mod:`repro.api.registry` — adding a pass is "write a class, decorate
it", and the CLI picks it up by name.

Emission goes through :meth:`AnalysisPass.emit`, which drops findings
whose line carries a matching ``# analysis: allow[RULE]`` pragma, so
every rule is suppressible the same way without per-pass bookkeeping.
"""

from __future__ import annotations

import abc
from typing import Dict, List, Optional, Tuple, Type

from .findings import Finding
from .walker import Project, SourceFile

PASSES: Dict[str, Type["AnalysisPass"]] = {}


def register_pass(cls: Type["AnalysisPass"]) -> Type["AnalysisPass"]:
    if not cls.name:
        raise ValueError(f"{cls.__name__} has no name")
    if cls.name in PASSES:
        raise ValueError(f"duplicate pass name {cls.name!r}")
    PASSES[cls.name] = cls
    return cls


def all_passes() -> Tuple[str, ...]:
    return tuple(sorted(PASSES))


class AnalysisPass(abc.ABC):
    name: str = ""
    description: str = ""

    def __init__(self) -> None:
        self.findings: List[Finding] = []

    @abc.abstractmethod
    def run(self, project: Project) -> List[Finding]:
        """Analyse ``project`` and return the surviving findings."""

    def emit(self, sf: Optional[SourceFile], line: int, rule: str,
             message: str, path: Optional[str] = None) -> None:
        """Record a finding unless a suppression pragma covers it.
        ``sf=None`` (runtime-reflection findings with no source handle)
        skips suppression; ``path`` overrides the rendered location."""
        if sf is not None and sf.suppressed(line, rule):
            return
        self.findings.append(Finding(
            pass_name=self.name, rule=rule,
            path=path or (sf.rel if sf is not None else "<runtime>"),
            line=line, message=message))
