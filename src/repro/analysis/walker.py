"""Source loading for the analysis passes: files, comments, pragmas.

Two pragma families ride in comments (both attach to the line they are
written on, or — for ``# hot-path`` — to the ``def`` they precede):

  * ``# hot-path`` marks a function outside ``repro/kernels`` as a
    serving hot path, opting it into the hot-path purity checks for
    interpreted code (no per-point numpy conversions inside loops, no
    host syncs);
  * ``# analysis: allow[RULE1,RULE2]`` (or ``allow[*]``) suppresses the
    named rules on that line — the per-finding escape hatch.  Suppression
    is per line, not per file: a pragma never baselines a whole module.

``SourceFile`` parses a module once (AST + comment map + parent links —
``node.parent`` is set on every AST node so passes can walk upward, e.g.
"is this call inside a loop inside a hot function").  ``Project`` walks a
root directory for the package's modules and caches the parses; tests
point it at fixture trees.
"""

from __future__ import annotations

import ast
import io
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterator, List, Optional

_ALLOW = re.compile(r"analysis:\s*allow\[([^\]]*)\]")
_HOT = re.compile(r"#\s*hot-path\b")


class SourceFile:
    """One parsed module: AST (with parent links), comments, pragmas."""

    def __init__(self, text: str, rel: str):
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=rel)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node  # type: ignore[attr-defined]
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:  # pragma: no cover — ast would have raised
            pass

    # ------------------------------------------------------------------ #
    def suppressed(self, line: int, rule: str) -> bool:
        """True when ``# analysis: allow[...]`` on ``line`` (or the line
        above it) names ``rule`` or ``*``."""
        for ln in (line, line - 1):
            c = self.comments.get(ln)
            if not c:
                continue
            m = _ALLOW.search(c)
            if m:
                allowed = {r.strip() for r in m.group(1).split(",")}
                if "*" in allowed or rule in allowed:
                    return True
        return False

    def is_hot_path(self, fn: ast.AST) -> bool:
        """True when ``fn``'s def line, a decorator line, or the line
        directly above carries a ``# hot-path`` comment."""
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        first = min([fn.lineno] + [d.lineno for d in fn.decorator_list])
        for ln in range(first - 1, fn.body[0].lineno):
            c = self.comments.get(ln)
            if c and _HOT.search(c):
                return True
        return False

    def functions(self) -> Iterator[ast.FunctionDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.FunctionDef):
                yield node


def enclosing(node: ast.AST, *types: type) -> Optional[ast.AST]:
    """Nearest ancestor of ``node`` that is an instance of ``types``,
    or None (walks the parent links SourceFile installed)."""
    cur = getattr(node, "parent", None)
    while cur is not None:
        if isinstance(cur, types):
            return cur
        cur = getattr(cur, "parent", None)
    return None


class Project:
    """The analysed source tree: ``root`` contains the package directory
    (for the real repo, ``src`` containing ``repro``; for fixtures, any
    directory containing a ``repro``-shaped subtree)."""

    def __init__(self, root: Path, package: str = "repro"):
        self.root = Path(root)
        self.package = package
        self._cache: Dict[str, SourceFile] = {}

    @classmethod
    def locate(cls) -> "Project":
        """Project over the importable ``repro`` package's own tree."""
        import repro

        pkg_dir = Path(list(repro.__path__)[0])
        return cls(pkg_dir.parent)

    # ------------------------------------------------------------------ #
    def _load(self, path: Path) -> Optional[SourceFile]:
        rel = str(path.relative_to(self.root / self.package))
        if rel not in self._cache:
            try:
                self._cache[rel] = SourceFile(path.read_text(), rel)
            except (OSError, SyntaxError):
                return None
        return self._cache[rel]

    def sources(self) -> List[SourceFile]:
        """Every parseable module under the package, sorted by path."""
        out = []
        pkg = self.root / self.package
        for path in sorted(pkg.rglob("*.py")):
            sf = self._load(path)
            if sf is not None:
                out.append(sf)
        return out

    def source(self, rel: str) -> Optional[SourceFile]:
        """The module at ``rel`` (path relative to the package dir)."""
        path = self.root / self.package / rel
        return self._load(path) if path.is_file() else None
