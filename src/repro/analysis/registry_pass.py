"""registry-conformance — every backend honours the ClusterIndex protocol.

The backend registry is the repo's extension point: serving, sharding,
checkpointing, and the transports all assume any registered backend
upholds the full :class:`~repro.api.index.ClusterIndex` contract.  The
parts Python enforces (abstract methods) fail loudly; the parts it does
not — snapshot/restore symmetry and the ``native_component_queries``
capability flag that the sharded incremental merge trusts — fail as
wrong clusters months later.  This pass checks them by reflection over
the concrete ClusterIndex subclass closure:

  REG001  concrete-looking backend class still has abstract methods
  REG002  persistence overridden asymmetrically (``_state`` without
          ``_load_state``, or ``snapshot`` without ``restore``)
  REG003  ``native_component_queries`` is truthy but ``core_anchor_of``
          is inherited from the raising base — the advertised capability
          does not exist
  REG004  ``core_anchor_of`` is overridden but the class never declares
          ``native_component_queries`` (class attribute or instance
          assignment) — the capability exists but is never advertised,
          so the sharded merge silently falls back to rebuild-per-query
  REG005  registered factory does not take exactly one required
          parameter (the ClusterConfig)
"""

from __future__ import annotations

import inspect
from typing import Iterable, List, Optional

from .base import AnalysisPass, register_pass
from .findings import Finding
from .walker import Project, SourceFile


def _subclass_closure(base: type) -> List[type]:
    out, todo = [], [base]
    while todo:
        cls = todo.pop()
        for sub in cls.__subclasses__():
            if sub not in out:
                out.append(sub)
                todo.append(sub)
    return sorted(out, key=lambda c: c.__name__)


def _overrides(cls: type, base: type, name: str) -> bool:
    return getattr(cls, name, None) is not getattr(base, name, None)


class _Location:
    """Map a class back to (SourceFile, line) for pragma suppression."""

    def __init__(self, project: Project):
        self._project = project

    def of(self, cls: type):
        try:
            path = inspect.getsourcefile(cls)
            line = inspect.getsourcelines(cls)[1]
        except (OSError, TypeError):
            return None, 0
        if path is None:
            return None, 0
        marker = f"/{self._project.package}/"
        pos = path.rfind(marker)
        if pos < 0:
            return None, line
        return self._project.source(path[pos + len(marker):]), line


@register_pass
class RegistryConformance(AnalysisPass):
    name = "registry-conformance"
    description = ("backends implement the full ClusterIndex protocol "
                   "with consistent capability flags")

    #: injectable for fixture tests: explicit class list + base class
    def __init__(self, classes: Optional[Iterable[type]] = None,
                 base: Optional[type] = None):
        super().__init__()
        self._classes = None if classes is None else list(classes)
        self._base = base

    def run(self, project: Project) -> List[Finding]:
        base = self._base
        classes = self._classes
        if base is None or classes is None:
            import repro.api  # noqa: F401 — registers the built-in backends
            import repro.shard  # noqa: F401 — registers "sharded"
            import repro.tiered  # noqa: F401 — TieredIndex into the closure
            from ..api.index import ClusterIndex

            base = base or ClusterIndex
            if classes is None:
                classes = _subclass_closure(ClusterIndex)
        loc = _Location(project)
        for cls in classes:
            self._check_class(cls, base, *loc.of(cls))
        self._check_factories(project, loc)
        return self.findings

    # ------------------------------------------------------------------ #
    def _check_class(self, cls: type, base: type,
                     sf: Optional[SourceFile], line: int) -> None:
        name = cls.__name__
        abstract = sorted(getattr(cls, "__abstractmethods__", ()))
        if abstract and not name.startswith("_"):
            self.emit(sf, line, "REG001",
                      f"{name} leaves abstract methods unimplemented: "
                      f"{', '.join(abstract)}", path=sf.rel if sf else name)
            return
        for a, b in (("_state", "_load_state"), ("snapshot", "restore")):
            if _overrides(cls, base, a) != _overrides(cls, base, b):
                self.emit(sf, line, "REG002",
                          f"{name} overrides {a!r} and {b!r} asymmetrically "
                          "— snapshots that cannot round-trip",
                          path=sf.rel if sf else name)
        flag = bool(cls.__dict__.get("native_component_queries", False))
        has_anchor = _overrides(cls, base, "core_anchor_of")
        if flag and not has_anchor:
            self.emit(sf, line, "REG003",
                      f"{name} advertises native_component_queries but "
                      "inherits the raising core_anchor_of",
                      path=sf.rel if sf else name)
        elif has_anchor and not flag and not self._declares_flag(cls):
            self.emit(sf, line, "REG004",
                      f"{name} implements core_anchor_of but never "
                      "declares native_component_queries — the sharded "
                      "merge will not use it", path=sf.rel if sf else name)

    @staticmethod
    def _declares_flag(cls: type) -> bool:
        """Instance-level capability declaration (e.g. ShardedIndex sets
        the flag per transport handshake in __init__)."""
        try:
            src = inspect.getsource(cls)
        except (OSError, TypeError):
            return False
        return "native_component_queries" in src

    def _check_factories(self, project: Project, loc: _Location) -> None:
        if self._classes is not None:
            return  # fixture mode: no live registry to inspect
        from ..api import registry as reg

        for name in reg.available_backends():
            factory = reg._REGISTRY[name]
            try:
                sig = inspect.signature(factory)
            except (TypeError, ValueError):  # pragma: no cover
                continue
            required = [p for p in sig.parameters.values()
                        if p.default is p.empty
                        and p.kind in (p.POSITIONAL_ONLY,
                                       p.POSITIONAL_OR_KEYWORD)]
            if len(required) != 1:
                sf, line = loc.of(factory)  # type: ignore[arg-type]
                self.emit(sf, line, "REG005",
                          f"backend factory {name!r} must take exactly one "
                          "required parameter (the ClusterConfig), got "
                          f"{len(required)}")
