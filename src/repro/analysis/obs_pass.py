"""obs-discipline — span/timer lifecycle, checked.

A trace span or latency timer is a scope: it must close on every exit
path, including exceptions, or the dump shows a span that never ended
(and the histogram silently loses the observation).  The context-manager
protocol is exactly that guarantee, so the rule is simply that the
protocol is used:

  OBS001  a ``.span(...)`` / ``.timer(...)`` call in ``service/`` or
          ``shard/`` that is not a ``with``-statement item — open-coded
          ``__enter__``/manual timing can leak the span open on an
          exception path

Scoped to the protocol and coordinator modules (the ones whose spans
cross the wire, where a leaked span corrupts a whole trace tree) — and to
the two instrument factories by name, so unrelated ``.timer()`` APIs
elsewhere never trip it.  Storing the context manager first
(``cm = h.timer()`` ... ``with cm:``) also trips the rule by design:
the repo's idiom is to open the scope where it is created.
"""

from __future__ import annotations

import ast
from typing import List, Set

from .base import AnalysisPass, register_pass
from .findings import Finding
from .walker import Project, SourceFile

_SCOPED_PREFIXES = ("service/", "shard/")
_INSTRUMENT_FACTORIES = ("span", "timer")


@register_pass
class ObsDiscipline(AnalysisPass):
    name = "obs-discipline"
    description = ("span/timer instruments in protocol modules are opened "
                   "as context managers, never left to leak on exceptions")

    def run(self, project: Project) -> List[Finding]:
        for sf in project.sources():
            if sf.rel.startswith(_SCOPED_PREFIXES):
                self._check(sf)
        return self.findings

    def _check(self, sf: SourceFile) -> None:
        # every call node that already is a with-item is compliant
        with_items: Set[int] = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    with_items.add(id(item.context_expr))
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not (isinstance(f, ast.Attribute)
                    and f.attr in _INSTRUMENT_FACTORIES):
                continue
            if id(node) in with_items:
                continue
            self.emit(sf, node.lineno, "OBS001",
                      f".{f.attr}(...) outside a with statement — open "
                      "span/timer scopes as context managers so they "
                      "close on every exit path")
