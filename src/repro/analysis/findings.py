"""Finding — one rule violation at one source location.

Findings are plain data: the CLI renders them as ``path:line: RULE
message [pass]`` lines or as JSON objects, and the exit code is driven by
their count.  Rule ids are stable strings (``PROTO001`` …) so suppression
pragmas (see :mod:`repro.analysis.walker`) and CI greps can target them.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str   # which analysis pass produced it
    rule: str        # stable rule id, e.g. "HOT001"
    path: str        # path relative to the analysed root (or module name)
    line: int        # 1-based line number (0 = whole file / no source)
    message: str     # human-readable description of the violation

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"{self.message} [{self.pass_name}]")

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)
