"""Streaming training-data pipeline with background prefetch and
dynamic-DBSCAN curation (the paper's technique as a first-class feature).

The pipeline yields fixed-shape token batches; an optional
:class:`CurationFilter` clusters example embeddings *online* (insertions
for arriving examples, deletions for expired ones — exactly the paper's
Add/Delete workload) and applies a policy:

  * ``dedup``      drop examples landing in an over-dense cluster;
  * ``balance``    downsample dominant clusters to even coverage;
  * ``novelty``    keep only examples that are noise/low-density (e.g. for
                   replay-buffer style continual pretraining).

The host-side structure updates run on the prefetch thread — off the
accelerator critical path (async curation).
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..api import ClusterConfig, NOISE, build_index


class SyntheticTokenStream:
    """Deterministic synthetic LM token stream (documents with topical
    structure so curation has something to find)."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int,
                 n_topics: int = 16, embed_dim: int = 16, seed: int = 0):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        self.n_topics = n_topics
        self.topic_centers = self.rng.normal(size=(n_topics, embed_dim))
        self.topic_token_bias = self.rng.integers(
            0, max(vocab_size - 100, 1), size=n_topics
        )
        self.embed_dim = embed_dim

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            topics = self.rng.integers(0, self.n_topics, size=self.batch)
            base = self.topic_token_bias[topics][:, None]
            toks = (base + self.rng.integers(0, 100, size=(self.batch, self.seq))) % self.vocab
            emb = self.topic_centers[topics] + 0.1 * self.rng.normal(
                size=(self.batch, self.embed_dim)
            )
            yield {
                "tokens": toks.astype(np.int32),
                "labels": np.roll(toks, -1, axis=1).astype(np.int32),
                "embeddings": emb.astype(np.float32),
                "topics": topics,
            }


class CurationFilter:
    """Online clustering of example embeddings with a sliding window."""

    def __init__(self, d: int, k: int = 10, t: int = 10, eps: float = 0.75,
                 policy: str = "balance", window: int = 50_000,
                 max_per_cluster_frac: float = 0.25, seed: int = 0,
                 backend: str = "batched", shards: int = 1,
                 transport: str = "local"):
        # shards > 1 shards the window by LSH key range (backend = inner);
        # transport="process" runs those shards out-of-process
        self.index = build_index(
            ClusterConfig(d=d, k=k, t=t, eps=eps, seed=seed,
                          backend=backend,
                          transport=transport).with_shards(shards)
        )
        self.policy = policy
        self.window = window
        self.max_frac = max_per_cluster_frac
        self._fifo: list = []
        self.n_seen = 0
        self.n_kept = 0

    def filter(self, embeddings: np.ndarray) -> np.ndarray:
        """Returns a boolean keep-mask for the rows of ``embeddings``."""
        n = embeddings.shape[0]
        ids = self.index.insert_batch(embeddings)
        self._fifo.extend(ids)
        # expire old points (sliding window -> DeletePoint workload)
        while len(self._fifo) > self.window:
            self.index.delete(self._fifo.pop(0))
        labels = self.index.labels(ids)
        sizes: Dict[int, int] = {}
        all_labels = self.index.labels()
        for v in all_labels.values():
            sizes[v] = sizes.get(v, 0) + 1
        total = max(1, len(all_labels))
        keep = np.ones(n, dtype=bool)
        for j, idx in enumerate(ids):
            lab = labels[idx]
            if self.policy == "novelty":
                keep[j] = lab == NOISE
            elif self.policy == "balance":
                keep[j] = (lab == NOISE) or (
                    sizes.get(lab, 0) / total <= self.max_frac
                )
            elif self.policy == "dedup":
                keep[j] = (lab == NOISE) or sizes.get(lab, 0) < self.index.cfg.k * 4
        self.n_seen += n
        self.n_kept += int(keep.sum())
        return keep

    def close(self) -> None:
        """Shut down the window index (worker processes, if any)."""
        self.index.close()


class Pipeline:
    """Prefetching iterator: source -> (curation) -> bounded queue."""

    def __init__(self, source, curation: Optional[CurationFilter] = None,
                 prefetch: int = 4):
        self.source = source
        self.curation = curation
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        for batch in self.source:
            if self._stop.is_set():
                return
            if self.curation is not None:
                keep = self.curation.filter(batch["embeddings"])
                if keep.sum() == 0:
                    continue
                idx = np.flatnonzero(keep)
                # refill to the fixed batch size by repeating kept rows
                fill = np.resize(idx, batch["tokens"].shape[0])
                batch = {k: v[fill] for k, v in batch.items()}
            self.q.put(batch)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
