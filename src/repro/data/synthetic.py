"""Synthetic datasets for the paper's experiments.

``blobs`` is exactly the paper's synthetic dataset (mixture of Gaussians,
n=200k, d=10, 10 clusters by default).  The real datasets in Table 1
(Letter/MNIST/Fashion-MNIST/KDDCup99/Covertype) are unavailable offline, so
``dataset_standin`` generates distribution-matched stand-ins with the same
(n, d, #clusters) and standardisation; EXPERIMENTS.md reports the numbers
as relative comparisons, not as claims about the original data.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# (n, d, n_clusters) from the paper's Table 1 (post-PCA dims where applied)
DATASET_SPECS: Dict[str, Tuple[int, int, int]] = {
    "letter": (20000, 16, 26),
    "mnist": (70000, 20, 10),
    "fashion-mnist": (70000, 20, 10),
    "blobs": (200000, 10, 10),
    "kddcup99": (494000, 20, 23),
    "covertype": (581012, 54, 7),
}


def blobs(
    n: int = 200000,
    d: int = 10,
    n_clusters: int = 10,
    cluster_std: float = 0.25,
    spread: float = 4.0,
    seed: int = 0,
    standardize: bool = True,
):
    """Mixture-of-Gaussians blobs; returns (X, labels)."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-spread, spread, size=(n_clusters, d))
    labels = rng.integers(0, n_clusters, size=n)
    X = centers[labels] + rng.normal(0.0, cluster_std, size=(n, d))
    if standardize:
        X = (X - X.mean(axis=0)) / (X.std(axis=0) + 1e-12)
    return X.astype(np.float64), labels.astype(np.int64)


def dataset_standin(name: str, seed: int = 0, scale: float = 1.0):
    """Distribution-matched stand-in for one of the paper's datasets.

    Gaussian mixture with unequal cluster weights plus 5% uniform
    background noise (real datasets are not clean blobs); standardised to
    zero mean / unit variance per dimension like the paper's preprocessing.
    ``scale`` < 1 shrinks n for CI-speed runs.
    """
    n, d, c = DATASET_SPECS[name]
    n = max(1000, int(n * scale))
    rng = np.random.default_rng(seed + hash(name) % (2**31))
    centers = rng.uniform(-3.5, 3.5, size=(c, d))
    # unequal cluster weights (Zipf-ish), as in real data
    w = 1.0 / np.arange(1, c + 1)
    w /= w.sum()
    labels = rng.choice(c, size=n, p=w)
    stds = rng.uniform(0.15, 0.5, size=c)
    X = centers[labels] + rng.normal(0.0, 1.0, size=(n, d)) * stds[labels][:, None]
    # background noise points
    n_noise = n // 20
    noise_rows = rng.choice(n, size=n_noise, replace=False)
    X[noise_rows] = rng.uniform(-4.5, 4.5, size=(n_noise, d))
    labels[noise_rows] = -1
    X = (X - X.mean(axis=0)) / (X.std(axis=0) + 1e-12)
    return X.astype(np.float64), labels.astype(np.int64)
