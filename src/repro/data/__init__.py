from .synthetic import blobs, dataset_standin, DATASET_SPECS  # noqa: F401
