"""Quickstart: dynamic DBSCAN in a dozen lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DynamicDBSCAN, adjusted_rand_index
from repro.data import blobs

# 2000 points from 5 Gaussian blobs, streamed one at a time
X, y = blobs(n=2000, d=5, n_clusters=5, cluster_std=0.15, seed=0)

db = DynamicDBSCAN(d=5, k=10, t=10, eps=0.4, seed=0)
ids = [db.add_point(X[i]) for i in range(len(X))]

# clusters update dynamically: delete the first 500 points again
for i in ids[:500]:
    db.delete_point(i)

labels = db.labels()                     # bulk labels (noise = -1)
cluster_of_point_700 = db.get_cluster(ids[700])   # O(log n) point query

pred = np.array([labels[i] for i in ids[500:]])
print("ARI vs ground truth:", round(adjusted_rand_index(y[500:], pred), 4))
print("clusters:", len({v for v in pred if v != -1}),
      " noise points:", int((pred == -1).sum()))
