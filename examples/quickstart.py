"""Quickstart: dynamic DBSCAN through the unified repro.api in a dozen lines.

    PYTHONPATH=src python examples/quickstart.py [--backend dynamic]
"""
import argparse

import numpy as np

from repro.api import ClusterConfig, available_backends, build_index
from repro.core import adjusted_rand_index
from repro.data import blobs

ap = argparse.ArgumentParser()
ap.add_argument("--backend", default="dynamic", choices=available_backends())
args = ap.parse_args()

# 2000 points from 5 Gaussian blobs, streamed one at a time
X, y = blobs(n=2000, d=5, n_clusters=5, cluster_std=0.15, seed=0)

db = build_index(ClusterConfig(d=5, k=10, t=10, eps=0.4, seed=0,
                               backend=args.backend))
ids = db.insert_batch(X)

# clusters update dynamically: delete the first 500 points again
db.delete_batch(ids[:500])

labels = db.labels()                     # bulk labels (noise = -1)
cluster_of_point_700 = db.label(ids[700])   # O(log n) point query

pred = np.array([labels[i] for i in ids[500:]])
print("backend:", args.backend)
print("ARI vs ground truth:", round(adjusted_rand_index(y[500:], pred), 4))
print("clusters:", len({v for v in pred if v != -1}),
      " noise points:", int((pred == -1).sum()))
