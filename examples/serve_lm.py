"""Serve a small model with batched requests + request clustering.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "mamba2-780m", "--smoke", "--requests", "12",
                "--batch", "4", "--cluster"])
