"""End-to-end driver: train a granite-family LM for a few hundred steps on
the synthetic pipeline with DBSCAN curation enabled.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Defaults are CPU-feasible (~5M params); pass --full-100m on real hardware
for the ~124M-param preset (12 layers x d_model 768, vocab 32k).
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full-100m", action="store_true")
    args = ap.parse_args()
    argv = [
        "--arch", "granite-20b",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--curation", "balance",
        "--ckpt-every", "100",
    ]
    argv += ["--preset", "100m"] if args.full_100m else [
        "--smoke", "--d-model-override", "512"]
    train_main(argv)
