"""Data-curation demo: the dynamic-DBSCAN filter inside the streaming
pipeline — dominant topics get throttled, the topic mix evens out.

    PYTHONPATH=src python examples/curation_pipeline.py
"""
import numpy as np

from repro.data.pipeline import CurationFilter, Pipeline, SyntheticTokenStream


class SkewedStream(SyntheticTokenStream):
    """80% of examples come from topic 0."""
    def __iter__(self):
        for batch in super().__iter__():
            skew = self.rng.random(self.batch) < 0.8
            batch["topics"] = np.where(skew, 0, batch["topics"])
            batch["embeddings"][skew] = (
                self.topic_centers[0] + 0.05 * self.rng.normal(
                    size=(int(skew.sum()), self.embed_dim))
            )
            yield batch


src = SkewedStream(vocab_size=1000, seq_len=32, batch=64, n_topics=8, seed=0)
cf = CurationFilter(d=src.embed_dim, k=8, t=8, eps=0.6,
                    policy="balance", max_per_cluster_frac=0.3)
pipe = Pipeline(iter(src), curation=cf)

before, after = [], []
for i in range(20):
    b = next(pipe)
    after.append(b["topics"])
pipe.close()
after = np.concatenate(after)
frac0 = float((after == 0).mean())
print(f"raw stream: 80% topic-0   curated stream: {frac0:.0%} topic-0")
print(f"curation kept {cf.n_kept}/{cf.n_seen} examples "
      f"({cf.n_kept/cf.n_seen:.0%})")
