"""The paper's core experiment, end to end: stream a dynamic dataset into
DynamicDBSCAN (insertions + sliding-window deletions) and track clustering
quality against EMZ-recompute — Figure 2's workload at laptop scale.

    PYTHONPATH=src python examples/streaming_clustering.py
"""
import time

import numpy as np

from repro.core import (DynamicDBSCAN, EMZRecompute, GridLSH,
                        adjusted_rand_index)
from repro.data import blobs

n, d, batch = 12000, 8, 1000
X, y = blobs(n=n, d=d, n_clusters=8, cluster_std=0.2, seed=3)
k, t, eps = 10, 10, 0.5

lsh = GridLSH(d, eps, t, seed=0)
dyn = DynamicDBSCAN(d, k, t, eps, lsh=lsh)
emz = EMZRecompute(d, k, t, eps, lsh=lsh)

t_dyn = t_emz = 0.0
ids = []
for s in range(0, n, batch):
    xb = X[s : s + batch]
    t0 = time.time(); ids += [dyn.add_point(p) for p in xb]; t_dyn += time.time() - t0
    t0 = time.time(); emz_labels = emz.add_batch(xb); t_emz += time.time() - t0
    lab = dyn.labels(ids)
    pred = np.array([lab[i] for i in ids])
    ari_d = adjusted_rand_index(y[: s + batch], pred)
    ari_e = adjusted_rand_index(y[: s + batch], emz_labels)
    print(f"n={s+batch:6d}  DyDBSCAN ARI={ari_d:.3f} ({t_dyn:5.2f}s cum)   "
          f"EMZ ARI={ari_e:.3f} ({t_emz:5.2f}s cum)")

# sliding-window deletions: expire the first half
t0 = time.time()
for i in ids[: n // 2]:
    dyn.delete_point(i)
print(f"deleted {n//2} points in {time.time()-t0:.2f}s "
      f"(repair scans fired: {dyn.n_repair_scans})")
lab = dyn.labels(ids[n // 2 :])
pred = np.array([lab[i] for i in ids[n // 2 :]])
print("post-expiry ARI:", round(adjusted_rand_index(y[n // 2 :], pred), 3))
