"""The paper's core experiment, end to end: stream a dynamic dataset into
a ClusterIndex (insertions + sliding-window deletions) and track clustering
quality against the EMZ-recompute baseline — Figure 2's workload at laptop
scale.  Both clusterers are built through repro.api, so swapping engines is
a CLI flag:

    PYTHONPATH=src python examples/streaming_clustering.py
    PYTHONPATH=src python examples/streaming_clustering.py --backend batched
    PYTHONPATH=src python examples/streaming_clustering.py --backend batched --shards 4
    PYTHONPATH=src python examples/streaming_clustering.py --backend batched \
        --shards 4 --transport process     # shards as spawned server processes
"""
import argparse
import time

import numpy as np

from repro.api import ClusterConfig, available_backends, build_index
from repro.core import adjusted_rand_index
from repro.data import blobs

ap = argparse.ArgumentParser()
ap.add_argument("--backend", default="dynamic", choices=available_backends())
ap.add_argument("--baseline", default="emz-static", choices=available_backends())
ap.add_argument("--shards", type=int, default=0,
                help="shard the engine under test across S LSH key ranges")
ap.add_argument("--transport", default="local", choices=("local", "process"),
                help="reach the shards in-process or as spawned servers")
ap.add_argument("--sample-rate", type=float, default=0.2,
                help="sampled-core fraction for --backend approx/tiered "
                     "(ignored by the exact engines)")
args = ap.parse_args()

n, d, batch = 12000, 8, 1000
X, y = blobs(n=n, d=d, n_clusters=8, cluster_std=0.2, seed=3)
cfg = ClusterConfig(d=d, k=10, t=10, eps=0.5, seed=0,
                    transport=args.transport, sample_rate=args.sample_rate)

dyn = build_index(cfg.replace(backend=args.backend).with_shards(args.shards))
emz = build_index(cfg.replace(backend=args.baseline))

t_dyn = t_emz = 0.0
ids = []
for s in range(0, n, batch):
    xb = X[s : s + batch]
    t0 = time.time(); ids += dyn.insert_batch(xb); t_dyn += time.time() - t0
    t0 = time.time()
    emz.insert_batch(xb)
    emz_lab = emz.labels()
    t_emz += time.time() - t0
    lab = dyn.labels(ids)
    pred = np.array([lab[i] for i in ids])
    pred_e = np.array([emz_lab[i] for i in sorted(emz_lab)])
    ari_d = adjusted_rand_index(y[: s + batch], pred)
    ari_e = adjusted_rand_index(y[: s + batch], pred_e)
    print(f"n={s+batch:6d}  {args.backend} ARI={ari_d:.3f} ({t_dyn:5.2f}s cum)   "
          f"{args.baseline} ARI={ari_e:.3f} ({t_emz:5.2f}s cum)")

# sliding-window deletions: expire the first half
t0 = time.time()
dyn.delete_batch(ids[: n // 2])
print(f"deleted {n//2} points in {time.time()-t0:.2f}s "
      f"(repair scans fired: {dyn.stats().get('n_repair_scans', 0)})")
lab = dyn.labels(ids[n // 2 :])
pred = np.array([lab[i] for i in ids[n // 2 :]])
print("post-expiry ARI:", round(adjusted_rand_index(y[n // 2 :], pred), 3))
dyn.close()  # shuts shard worker processes down under --transport process
emz.close()
